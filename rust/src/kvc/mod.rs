//! KV-cache (KVC) management: the allocation-policy axis of Table 1.
//!
//! The module is a policy/mechanism split:
//!
//!  * [`alloc`] — the public face: the [`Allocator`] trait (lease-style
//!    grants, typed [`AllocOutcome`]s) and its implementations
//!    [`MaxAlloc`] / [`BlockAlloc`] / [`ExactAlloc`] plus the composable
//!    [`Pipelined`] wrapper that layers §3.2 KVC pipelining over any
//!    inner allocator. Pick one by name with [`by_name`].
//!  * [`BlockPool`] (crate-private) — the mechanism: block-granular
//!    accounting (`block_size` tokens per block, 32 by default, like
//!    vLLM's PagedAttention) with a reservation carve-out (§3.3).
//!    Schedulers can no longer reach it; all allocation flows through
//!    [`Allocator`] handles held by `World`.
//!  * [`pipeline`] — the host/guest registry behind [`Pipelined`]
//!    ("Russian nesting dolls" span lending, Fig 7).
//!
//! All capacity is measured in **tokens**; physical allocation is
//! block-granular and rounds up.

pub mod alloc;
pub mod pipeline;

pub use alloc::{
    all_allocators, by_name, canonical_alloc_name, AllocOutcome, AllocStats, AllocTally,
    Allocator, BlockAlloc, Demand, ExactAlloc, Lease, MaxAlloc, Pipelined, PoolCore, Released,
};

use crate::core::ReqId;

/// Why an allocation request could not be satisfied.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum AllocError {
    /// Not enough unreserved free blocks.
    OutOfBlocks { needed: u32, free: u32 },
}

/// Which capacity class an allocation may draw from (§3.3: a slice of the
/// pool is carved out for PT admission and under-provision rescue).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReserveClass {
    /// Cannot dip below the reserved watermark.
    Normal,
    /// May consume the reserved carve-out.
    Reserved,
}

/// Per-request allocation record.
#[derive(Debug, Clone)]
pub(crate) struct Alloc {
    /// Blocks owned by this request.
    pub blocks: u32,
    /// Tokens actually written into owned blocks (<= blocks * block_size).
    pub written: u32,
    /// Class charged by the most recent grant (reported in [`Lease`]).
    pub class: ReserveClass,
}

impl Default for Alloc {
    fn default() -> Self {
        Alloc { blocks: 0, written: 0, class: ReserveClass::Normal }
    }
}

/// Block-granular KVC pool with a reservation carve-out. This is the
/// *mechanism* behind every [`Allocator`]; nothing outside `kvc` touches
/// it directly.
#[derive(Debug, Clone)]
pub(crate) struct BlockPool {
    block_size: u32,
    total_blocks: u32,
    free_blocks: u32,
    /// Blocks set aside for PTs / under-provision rescue (§3.3). Normal
    /// allocations cannot dip below this many free blocks; reserved
    /// allocations can.
    reserved_blocks: u32,
    /// Dense per-request slab keyed by `ReqId` (request ids are small
    /// integers — trace index in the sim, slot id on the real path), so
    /// every allocator op is a direct index instead of a hash lookup.
    allocs: Vec<Option<Alloc>>,
    /// Live lease count (slots with `Some`), so emptiness checks and
    /// invariant sweeps don't scan the slab.
    live: usize,
    /// Σ written tokens over live leases, maintained incrementally so
    /// `total_written` (the per-iteration KVC-utilization numerator) is
    /// O(1) instead of a slab sweep.
    written_total: u64,
    /// Cumulative counters for metrics.
    pub alloc_failures: u64,
    pub alloc_calls: u64,
}

impl BlockPool {
    pub fn new(capacity_tokens: u32, block_size: u32, reserve_tokens: u32) -> Self {
        assert!(block_size > 0);
        let total_blocks = capacity_tokens / block_size;
        let reserved_blocks = reserve_tokens.div_ceil(block_size);
        assert!(reserved_blocks <= total_blocks, "reservation exceeds capacity");
        BlockPool {
            block_size,
            total_blocks,
            free_blocks: total_blocks,
            reserved_blocks,
            allocs: Vec::new(),
            live: 0,
            written_total: 0,
            alloc_failures: 0,
            alloc_calls: 0,
        }
    }

    /// Ensure the slab has a (possibly fresh) record for `id`.
    fn ensure_slot(&mut self, id: ReqId) {
        if id >= self.allocs.len() {
            self.allocs.resize_with(id + 1, || None);
        }
        if self.allocs[id].is_none() {
            self.allocs[id] = Some(Alloc::default());
            self.live += 1;
        }
    }

    fn slot(&self, id: ReqId) -> Option<&Alloc> {
        self.allocs.get(id).and_then(|a| a.as_ref())
    }

    pub fn block_size(&self) -> u32 {
        self.block_size
    }

    pub fn capacity_tokens(&self) -> u32 {
        self.total_blocks * self.block_size
    }

    pub fn free_tokens(&self, class: ReserveClass) -> u32 {
        let free = match class {
            ReserveClass::Normal => self.free_blocks.saturating_sub(self.reserved_blocks),
            ReserveClass::Reserved => self.free_blocks,
        };
        free * self.block_size
    }

    pub fn reserve_tokens(&self) -> u32 {
        self.reserved_blocks * self.block_size
    }

    /// Blocks needed to hold `tokens` tokens (round up).
    fn blocks_for(&self, tokens: u32) -> u32 {
        tokens.div_ceil(self.block_size)
    }

    /// Allocate capacity for `tokens` more tokens for `id` (cumulative:
    /// extends the existing allocation). Fails atomically; on success
    /// returns the number of blocks newly taken from the free list.
    pub fn alloc_tokens(
        &mut self,
        id: ReqId,
        tokens: u32,
        class: ReserveClass,
    ) -> Result<u32, AllocError> {
        self.alloc_calls += 1;
        let bs = self.block_size;
        let available = match class {
            ReserveClass::Normal => self.free_blocks.saturating_sub(self.reserved_blocks),
            ReserveClass::Reserved => self.free_blocks,
        };
        self.ensure_slot(id);
        let (capacity_now, written) = {
            let entry = self.allocs[id].as_ref().expect("slot ensured");
            (entry.blocks * bs, entry.written)
        };
        let needed = (written + tokens).saturating_sub(capacity_now).div_ceil(bs);
        if needed > available {
            self.alloc_failures += 1;
            return Err(AllocError::OutOfBlocks { needed, free: available });
        }
        let entry = self.allocs[id].as_mut().expect("slot ensured");
        entry.blocks += needed;
        entry.class = class;
        self.free_blocks -= needed;
        Ok(needed)
    }

    /// Ensure `id` can hold `total_tokens` written tokens, growing
    /// block-by-block (vLLM block-allocation). Returns blocks newly added.
    pub fn ensure_capacity(
        &mut self,
        id: ReqId,
        total_tokens: u32,
        class: ReserveClass,
    ) -> Result<u32, AllocError> {
        self.alloc_calls += 1;
        let need_total = self.blocks_for(total_tokens);
        let available = match class {
            ReserveClass::Normal => self.free_blocks.saturating_sub(self.reserved_blocks),
            ReserveClass::Reserved => self.free_blocks,
        };
        self.ensure_slot(id);
        let have = self.allocs[id].as_ref().expect("slot ensured").blocks;
        if need_total <= have {
            return Ok(0);
        }
        let needed = need_total - have;
        if needed > available {
            self.alloc_failures += 1;
            return Err(AllocError::OutOfBlocks { needed, free: available });
        }
        let entry = self.allocs[id].as_mut().expect("slot ensured");
        entry.blocks += needed;
        entry.class = class;
        self.free_blocks -= needed;
        Ok(needed)
    }

    /// Record `n` tokens written into `id`'s own allocation. Panics if the
    /// allocation cannot hold them (callers must allocate first) — this is
    /// the invariant the property tests drive.
    pub fn write_tokens(&mut self, id: ReqId, n: u32) {
        let bs = self.block_size;
        let entry = self
            .allocs
            .get_mut(id)
            .and_then(|a| a.as_mut())
            .expect("write to unallocated request");
        assert!(
            entry.written + n <= entry.blocks * bs,
            "KVC overflow for req {id}: written {} + {n} > capacity {}",
            entry.written,
            entry.blocks * bs,
        );
        entry.written += n;
        self.written_total += n as u64;
    }

    /// Restore `n` written tokens after a swap-in (the KV data returned
    /// from CPU memory). Requires capacity to already be allocated.
    pub fn restore_written(&mut self, id: ReqId, n: u32) {
        let bs = self.block_size;
        let entry = self
            .allocs
            .get_mut(id)
            .and_then(|a| a.as_mut())
            .expect("restore to unallocated request");
        assert!(
            entry.written + n <= entry.blocks * bs,
            "swap-in restore overflow for req {id}"
        );
        entry.written += n;
        self.written_total += n as u64;
    }

    /// Release `id`'s whole allocation, returning (blocks, written tokens).
    pub fn release(&mut self, id: ReqId) -> (u32, u32) {
        match self.allocs.get_mut(id).and_then(|a| a.take()) {
            Some(a) => {
                self.live -= 1;
                self.free_blocks += a.blocks;
                self.written_total -= a.written as u64;
                debug_assert!(self.free_blocks <= self.total_blocks);
                (a.blocks, a.written)
            }
            None => (0, 0),
        }
    }

    /// Shrink `id`'s allocation to exactly fit its written tokens (used
    /// when a time-synced group returns and over-provisioned space is
    /// reclaimed). Returns the blocks freed.
    pub fn trim_to_written(&mut self, id: ReqId) -> u32 {
        let need = match self.slot(id) {
            Some(entry) => self.blocks_for(entry.written),
            None => return 0,
        };
        let entry = self.allocs[id].as_mut().expect("checked above");
        let excess = entry.blocks.saturating_sub(need);
        entry.blocks -= excess;
        self.free_blocks += excess;
        excess
    }

    pub fn alloc_of(&self, id: ReqId) -> Option<&Alloc> {
        self.slot(id)
    }

    pub fn allocated_tokens(&self, id: ReqId) -> u32 {
        self.slot(id).map(|a| a.blocks * self.block_size).unwrap_or(0)
    }

    pub fn written_tokens(&self, id: ReqId) -> u32 {
        self.slot(id).map(|a| a.written).unwrap_or(0)
    }

    /// Total tokens written across all live requests (own allocations —
    /// pipelined guest writes are accounted by [`Pipelined`]). O(1): the
    /// counter is maintained by write/restore/release.
    pub fn total_written(&self) -> u64 {
        self.written_total
    }

    /// Total allocated capacity in tokens (Σ blocks × block_size).
    pub fn total_allocated(&self) -> u64 {
        (self.total_blocks - self.free_blocks) as u64 * self.block_size as u64
    }

    /// Internal consistency check (used by tests and debug assertions).
    pub fn check_invariants(&self) {
        let mut owned = 0u32;
        let mut written = 0u64;
        let mut live = 0usize;
        for (id, a) in self.allocs.iter().enumerate() {
            let Some(a) = a else { continue };
            live += 1;
            owned += a.blocks;
            written += a.written as u64;
            assert!(
                a.written <= a.blocks * self.block_size,
                "req {id} wrote past its allocation"
            );
        }
        assert_eq!(owned + self.free_blocks, self.total_blocks, "block accounting leak");
        assert_eq!(written, self.written_total, "written-token counter drift");
        assert_eq!(live, self.live, "live-lease counter drift");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool() -> BlockPool {
        BlockPool::new(1024, 32, 64) // 32 blocks, 2 reserved
    }

    #[test]
    fn capacity_and_reserve_rounding() {
        let p = BlockPool::new(1000, 32, 50);
        assert_eq!(p.capacity_tokens(), 31 * 32); // 1000/32 = 31 blocks
        assert_eq!(p.reserve_tokens(), 2 * 32); // ceil(50/32) = 2 blocks
    }

    #[test]
    fn exact_alloc_and_write() {
        let mut p = pool();
        p.alloc_tokens(1, 100, ReserveClass::Normal).unwrap();
        assert_eq!(p.allocated_tokens(1), 128); // 4 blocks
        p.write_tokens(1, 100);
        assert_eq!(p.written_tokens(1), 100);
        p.check_invariants();
    }

    #[test]
    #[should_panic(expected = "KVC overflow")]
    fn write_past_allocation_panics() {
        let mut p = pool();
        p.alloc_tokens(1, 32, ReserveClass::Normal).unwrap();
        p.write_tokens(1, 33);
    }

    #[test]
    fn normal_cannot_touch_reserve() {
        let mut p = pool();
        // 32 blocks total, 2 reserved -> 30 usable = 960 tokens.
        assert!(p.alloc_tokens(1, 960, ReserveClass::Normal).is_ok());
        assert!(p.alloc_tokens(2, 32, ReserveClass::Normal).is_err());
        assert!(p.alloc_tokens(2, 32, ReserveClass::Reserved).is_ok());
        assert_eq!(p.alloc_failures, 1);
        p.check_invariants();
    }

    #[test]
    fn ensure_capacity_grows_blockwise() {
        let mut p = pool();
        assert_eq!(p.ensure_capacity(1, 1, ReserveClass::Normal).unwrap(), 1);
        p.write_tokens(1, 1);
        // Tokens 2..=32 need no new block.
        assert_eq!(p.ensure_capacity(1, 32, ReserveClass::Normal).unwrap(), 0);
        assert_eq!(p.ensure_capacity(1, 33, ReserveClass::Normal).unwrap(), 1);
        assert_eq!(p.allocated_tokens(1), 64);
    }

    #[test]
    fn release_returns_blocks() {
        let mut p = pool();
        p.alloc_tokens(1, 500, ReserveClass::Normal).unwrap();
        let before = p.free_tokens(ReserveClass::Reserved);
        let (blocks, _) = p.release(1);
        assert_eq!(blocks, 16); // ceil(500/32)
        assert_eq!(p.free_tokens(ReserveClass::Reserved), before + 16 * 32);
        p.check_invariants();
    }

    #[test]
    fn trim_reclaims_overprovision() {
        let mut p = pool();
        p.alloc_tokens(1, 320, ReserveClass::Normal).unwrap(); // 10 blocks
        p.write_tokens(1, 40); // only 2 blocks worth
        let freed = p.trim_to_written(1);
        assert_eq!(freed, 8);
        assert_eq!(p.allocated_tokens(1), 64);
        p.check_invariants();
    }

    #[test]
    fn alloc_is_atomic_on_failure() {
        let mut p = pool();
        p.alloc_tokens(1, 900, ReserveClass::Normal).unwrap();
        let free_before = p.free_tokens(ReserveClass::Normal);
        assert!(p.alloc_tokens(2, 500, ReserveClass::Normal).is_err());
        assert_eq!(p.free_tokens(ReserveClass::Normal), free_before);
        assert_eq!(p.allocated_tokens(2), 0);
        p.check_invariants();
    }

    #[test]
    fn alloc_records_reserve_class() {
        let mut p = pool();
        p.alloc_tokens(1, 32, ReserveClass::Reserved).unwrap();
        assert_eq!(p.alloc_of(1).unwrap().class, ReserveClass::Reserved);
        p.alloc_tokens(1, 32, ReserveClass::Normal).unwrap();
        assert_eq!(p.alloc_of(1).unwrap().class, ReserveClass::Normal);
    }
}
