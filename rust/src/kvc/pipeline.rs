//! KVC pipelining (§3.2): "Russian nesting dolls" sharing of allocated but
//! not-yet-used KVC space.
//!
//! A **hosting** GT with an allocated span of `L` tokens lends its second
//! half `[L/2, L)` to a **hosted** (guest) GT whose predicted RL is at most
//! `L/2 - b` (`b` = safety buffer against under-prediction). Because the
//! batch is time-synced (every GT writes one token per iteration), the
//! guest finishes and releases the space no later than the host's write
//! head arrives. Each half can recursively host further guests at `L/4-b`,
//! `L/8-b`, ... (Fig 7b).
//!
//! This registry tracks the host/guest tree and detects the failure case:
//! an under-predicted guest still alive when the host's head reaches its
//! start offset must be **evicted** (preempted; copy-on-write to host
//! memory per the paper).

use crate::core::ReqId;

/// A guest's placement inside its host's span.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HostSlot {
    pub host: ReqId,
    /// Offset in tokens from the host's span start.
    pub offset: u32,
    /// Slot length in tokens (the guest may use up to this many).
    pub len: u32,
}

/// A candidate slot produced by [`candidate_slots`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Slot {
    pub offset: u32,
    pub len: u32,
    /// Nesting depth (1 = direct second half, 2 = quarter, ...).
    pub depth: u32,
}

/// Enumerate the nested lending slots of a span of `span_len` tokens.
///
/// Depth d contributes 2^(d-1) slots of length span_len / 2^d: the second
/// half of every depth-(d-1) sub-interval. A guest fits slot s iff its
/// predicted RL <= s.len - buffer. Enumeration stops when slots get
/// shorter than `min_len` (no GT could fit) or `max_depth` is reached.
pub fn candidate_slots(span_len: u32, min_len: u32, max_depth: u32) -> Vec<Slot> {
    let mut out = Vec::new();
    // Sub-intervals at the current depth, as (offset, len) pairs. Depth 0
    // is the whole span; lending splits each interval in half and lends
    // the right half.
    let mut intervals = vec![(0u32, span_len)];
    for depth in 1..=max_depth {
        let mut next = Vec::with_capacity(intervals.len() * 2);
        for (off, len) in intervals {
            let half = len / 2;
            if half < min_len.max(1) {
                continue;
            }
            out.push(Slot { offset: off + half, len: half, depth });
            // Both halves can be subdivided further: the left stays owned
            // by the same writer, the right belongs to the new guest.
            next.push((off, half));
            next.push((off + half, half));
        }
        if next.is_empty() {
            break;
        }
        intervals = next;
    }
    out
}

/// Host/guest relationship tracker. Both maps are dense slabs keyed by
/// `ReqId`, so every lookup on the per-iteration overrun/write paths is a
/// direct index (guest counts are small; slab slots are tiny).
#[derive(Debug, Default, Clone)]
pub struct PipeRegistry {
    guests_by_host: Vec<Vec<ReqId>>,
    slot_of: Vec<Option<HostSlot>>,
    /// Live guest count (`slot_of` entries that are `Some`).
    n_guests: usize,
    /// Cumulative eviction count (under-predicted guests) for metrics.
    pub evictions: u64,
}

impl PipeRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register `guest` occupying `[offset, offset+len)` of `host`'s span.
    /// Panics if the guest already has a slot (one host per guest).
    pub fn add_guest(&mut self, guest: ReqId, host: ReqId, offset: u32, len: u32) {
        assert!(guest != host, "request cannot host itself");
        if guest >= self.slot_of.len() {
            self.slot_of.resize(guest + 1, None);
        }
        let prev = self.slot_of[guest].replace(HostSlot { host, offset, len });
        assert!(prev.is_none(), "guest {guest} already hosted");
        self.n_guests += 1;
        if host >= self.guests_by_host.len() {
            self.guests_by_host.resize_with(host + 1, Vec::new);
        }
        self.guests_by_host[host].push(guest);
    }

    pub fn host_of(&self, guest: ReqId) -> Option<HostSlot> {
        self.slot_of.get(guest).copied().flatten()
    }

    pub fn guests_of(&self, host: ReqId) -> &[ReqId] {
        self.guests_by_host.get(host).map(|v| v.as_slice()).unwrap_or(&[])
    }

    pub fn is_guest(&self, id: ReqId) -> bool {
        self.host_of(id).is_some()
    }

    pub fn guest_count(&self) -> usize {
        self.n_guests
    }

    /// Remove a guest (it completed or was evicted). Returns its slot.
    pub fn release_guest(&mut self, guest: ReqId) -> Option<HostSlot> {
        let slot = self.slot_of.get_mut(guest).and_then(|s| s.take())?;
        self.n_guests -= 1;
        if let Some(v) = self.guests_by_host.get_mut(slot.host) {
            v.retain(|g| *g != guest);
        }
        Some(slot)
    }

    /// The host's write head advanced to `head` tokens within its span:
    /// return the guests whose slots the head has reached — they must be
    /// evicted NOW (still alive == under-predicted). Does not remove them;
    /// the caller decides (preempt + release_guest).
    pub fn overrun_guests(&self, host: ReqId, head: u32) -> Vec<ReqId> {
        self.guests_of(host)
            .iter()
            .copied()
            .filter(|g| {
                let s = self.slot_of[*g].expect("host list out of sync");
                head > s.offset
            })
            .collect()
    }

    /// The host is going away (completed / preempted / trimmed): detach and
    /// return all its DIRECT guests. Transitive guests keep their (now
    /// dangling) hosts — callers cascade by calling this per released host.
    pub fn remove_host(&mut self, host: ReqId) -> Vec<ReqId> {
        let guests = match self.guests_by_host.get_mut(host) {
            Some(v) => std::mem::take(v),
            None => return Vec::new(),
        };
        for g in &guests {
            if self.slot_of[*g].take().is_some() {
                self.n_guests -= 1;
            }
        }
        guests
    }

    /// Internal consistency (for tests): every slot's host lists it back.
    pub fn check_invariants(&self) {
        let mut live = 0usize;
        for (guest, slot) in self.slot_of.iter().enumerate() {
            let Some(slot) = slot else { continue };
            live += 1;
            assert!(
                self.guests_by_host
                    .get(slot.host)
                    .map(|v| v.contains(&guest))
                    .unwrap_or(false),
                "guest {guest} not in host {} list",
                slot.host
            );
            assert!(slot.len > 0);
        }
        assert_eq!(live, self.n_guests, "guest counter drift");
        for (host, guests) in self.guests_by_host.iter().enumerate() {
            for g in guests {
                assert_eq!(self.slot_of[*g].expect("dangling guest").host, host);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slots_follow_fig7_layout() {
        // Span of 32: depth 1 -> [16,32); depth 2 -> [8,16) and [24,32).
        let slots = candidate_slots(32, 4, 3);
        assert!(slots.contains(&Slot { offset: 16, len: 16, depth: 1 }));
        assert!(slots.contains(&Slot { offset: 8, len: 8, depth: 2 }));
        assert!(slots.contains(&Slot { offset: 24, len: 8, depth: 2 }));
        // Depth 3: quarters of each half.
        assert!(slots.contains(&Slot { offset: 4, len: 4, depth: 3 }));
        assert!(slots.contains(&Slot { offset: 28, len: 4, depth: 3 }));
    }

    #[test]
    fn slots_respect_min_len() {
        let slots = candidate_slots(32, 16, 5);
        assert_eq!(slots, vec![Slot { offset: 16, len: 16, depth: 1 }]);
    }

    #[test]
    fn slots_disjoint_per_branch() {
        // All depth-d slots must be pairwise disjoint.
        let slots = candidate_slots(64, 1, 4);
        for a in &slots {
            for b in &slots {
                if a == b {
                    continue;
                }
                let a_end = a.offset + a.len;
                let b_end = b.offset + b.len;
                let disjoint = a_end <= b.offset || b_end <= a.offset;
                let nested = (a.offset >= b.offset && a_end <= b_end)
                    || (b.offset >= a.offset && b_end <= a_end);
                assert!(disjoint || nested, "{a:?} vs {b:?} overlap without nesting");
            }
        }
    }

    #[test]
    fn add_release_roundtrip() {
        let mut r = PipeRegistry::new();
        r.add_guest(2, 1, 16, 16);
        r.add_guest(3, 1, 8, 8);
        r.check_invariants();
        assert_eq!(r.guests_of(1), &[2, 3]);
        assert_eq!(r.host_of(2), Some(HostSlot { host: 1, offset: 16, len: 16 }));
        let slot = r.release_guest(2).unwrap();
        assert_eq!(slot.offset, 16);
        assert_eq!(r.guests_of(1), &[3]);
        r.check_invariants();
    }

    #[test]
    fn overrun_detection() {
        let mut r = PipeRegistry::new();
        r.add_guest(2, 1, 16, 16);
        assert!(r.overrun_guests(1, 16).is_empty()); // head AT offset: ok
        assert_eq!(r.overrun_guests(1, 17), vec![2]); // head past: evict
    }

    #[test]
    fn overrun_failure_path_evicts_under_predicted_guest() {
        // The §3.2 failure case at registry level: a guest still alive
        // (slot registered) when the host's write head reaches its offset
        // keeps being reported until the caller evicts it; eviction
        // (release) then clears the report and the registry stays sound.
        let mut r = PipeRegistry::new();
        r.add_guest(2, 1, 8, 8); // under-predicted: still alive at head 9
        r.add_guest(3, 1, 4, 4); // deeper slot, overrun even earlier
        for head in 9..12 {
            let over = r.overrun_guests(1, head);
            assert!(over.contains(&2) && over.contains(&3), "head={head}: {over:?}");
        }
        let slot = r.release_guest(3).unwrap();
        assert_eq!((slot.offset, slot.len), (4, 4));
        assert_eq!(r.overrun_guests(1, 9), vec![2], "evicted guest no longer reported");
        r.release_guest(2);
        assert!(r.overrun_guests(1, 100).is_empty());
        assert_eq!(r.guest_count(), 0);
        r.check_invariants();
    }

    #[test]
    fn remove_host_orphans_direct_guests() {
        let mut r = PipeRegistry::new();
        r.add_guest(2, 1, 16, 16);
        r.add_guest(3, 2, 8, 8); // nested inside guest 2
        let orphans = r.remove_host(1);
        assert_eq!(orphans, vec![2]);
        // 3 still registered under 2 (cascade is caller's job).
        assert!(r.is_guest(3));
        let orphans2 = r.remove_host(2);
        assert_eq!(orphans2, vec![3]);
        r.check_invariants();
    }

    #[test]
    #[should_panic(expected = "already hosted")]
    fn double_hosting_panics() {
        let mut r = PipeRegistry::new();
        r.add_guest(2, 1, 16, 16);
        r.add_guest(2, 3, 8, 8);
    }
}
