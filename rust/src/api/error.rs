//! Structured serving-error taxonomy with a stable HTTP mapping.

use super::types::FinishReason;

/// Why the serving front-end refused or failed a request. Every variant
/// has a stable `kind()` string (machine-readable) and an HTTP status.
#[derive(Debug, Clone, PartialEq)]
pub enum ServeError {
    /// Malformed request: bad JSON, missing fields, empty prompt, zero
    /// token budget. HTTP 400.
    InvalidRequest(String),
    /// Prompt exceeds the engine's prefill window. HTTP 400.
    PromptTooLong { len: usize, max: usize },
    /// Admission queue at capacity — load shed. HTTP 429.
    QueueFull { inflight: usize, limit: usize },
    /// The SLO budget cannot be met even on an idle engine, so admitting
    /// the request would only waste capacity. HTTP 503.
    SloInfeasible { needed_s: f64, budget_s: f64 },
    /// The per-key token bucket is empty — the client is sending faster
    /// than its configured sustained rate. HTTP 429 with a Retry-After
    /// hint.
    RateLimited { retry_after_s: f64 },
    /// The brownout overload controller is shedding this request class
    /// (tier 1: batch-class prompts; tier 2: everything). The condition
    /// is transient — HTTP 503 with a Retry-After hint, distinct from
    /// 429: the *server* is overloaded, not this client's send rate.
    Brownout { retry_after_s: f64 },
    /// The request was cancelled before completion. HTTP 499 (nginx's
    /// "client closed request" convention).
    Cancelled,
    /// The server is draining for shutdown: in-flight requests finish,
    /// new ones are refused. HTTP 503.
    ShuttingDown,
    /// The engine thread is gone. HTTP 503.
    EngineDown,
    /// Unexpected engine-side failure. HTTP 500.
    Internal(String),
}

impl ServeError {
    pub fn http_status(&self) -> u16 {
        match self {
            ServeError::InvalidRequest(_) | ServeError::PromptTooLong { .. } => 400,
            ServeError::QueueFull { .. } | ServeError::RateLimited { .. } => 429,
            ServeError::SloInfeasible { .. }
            | ServeError::Brownout { .. }
            | ServeError::ShuttingDown
            | ServeError::EngineDown => 503,
            ServeError::Cancelled => 499,
            ServeError::Internal(_) => 500,
        }
    }

    /// Stable machine-readable discriminator for clients.
    pub fn kind(&self) -> &'static str {
        match self {
            ServeError::InvalidRequest(_) => "invalid_request",
            ServeError::PromptTooLong { .. } => "prompt_too_long",
            ServeError::QueueFull { .. } => "queue_full",
            ServeError::SloInfeasible { .. } => "slo_infeasible",
            ServeError::RateLimited { .. } => "rate_limited",
            ServeError::Brownout { .. } => "brownout",
            ServeError::Cancelled => "cancelled",
            ServeError::ShuttingDown => "shutting_down",
            ServeError::EngineDown => "engine_down",
            ServeError::Internal(_) => "internal",
        }
    }

    /// The terminal lifecycle state this error corresponds to.
    pub fn finish_reason(&self) -> FinishReason {
        match self {
            ServeError::Cancelled => FinishReason::Cancelled,
            ServeError::InvalidRequest(_)
            | ServeError::PromptTooLong { .. }
            | ServeError::QueueFull { .. }
            | ServeError::RateLimited { .. }
            | ServeError::Brownout { .. }
            | ServeError::ShuttingDown
            | ServeError::SloInfeasible { .. } => FinishReason::Rejected,
            ServeError::EngineDown | ServeError::Internal(_) => FinishReason::Error,
        }
    }
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::InvalidRequest(msg) => write!(f, "invalid request: {msg}"),
            ServeError::PromptTooLong { len, max } => {
                write!(f, "prompt of {len} tokens exceeds the {max}-token prefill window")
            }
            ServeError::QueueFull { inflight, limit } => {
                write!(f, "admission queue full ({inflight} in flight, limit {limit})")
            }
            ServeError::SloInfeasible { needed_s, budget_s } => write!(
                f,
                "SLO budget {budget_s:.3}s is below the {needed_s:.3}s best-case service time"
            ),
            ServeError::RateLimited { retry_after_s } => {
                write!(f, "rate limited; retry after {retry_after_s:.3}s")
            }
            ServeError::Brownout { retry_after_s } => {
                write!(f, "browned out (overload shedding); retry after {retry_after_s:.3}s")
            }
            ServeError::Cancelled => write!(f, "request cancelled"),
            ServeError::ShuttingDown => write!(f, "server is shutting down"),
            ServeError::EngineDown => write!(f, "engine unavailable"),
            ServeError::Internal(msg) => write!(f, "internal error: {msg}"),
        }
    }
}

impl std::error::Error for ServeError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn http_status_mapping() {
        assert_eq!(ServeError::InvalidRequest("x".into()).http_status(), 400);
        assert_eq!(ServeError::PromptTooLong { len: 9, max: 8 }.http_status(), 400);
        assert_eq!(ServeError::QueueFull { inflight: 4, limit: 4 }.http_status(), 429);
        assert_eq!(
            ServeError::SloInfeasible { needed_s: 2.0, budget_s: 1.0 }.http_status(),
            503
        );
        assert_eq!(ServeError::RateLimited { retry_after_s: 0.5 }.http_status(), 429);
        assert_eq!(ServeError::Brownout { retry_after_s: 2.0 }.http_status(), 503);
        assert_eq!(ServeError::Cancelled.http_status(), 499);
        assert_eq!(ServeError::ShuttingDown.http_status(), 503);
        assert_eq!(ServeError::EngineDown.http_status(), 503);
        assert_eq!(ServeError::Internal("x".into()).http_status(), 500);
    }

    #[test]
    fn kinds_are_stable_and_distinct() {
        let kinds = [
            ServeError::InvalidRequest("x".into()).kind(),
            ServeError::PromptTooLong { len: 9, max: 8 }.kind(),
            ServeError::QueueFull { inflight: 4, limit: 4 }.kind(),
            ServeError::SloInfeasible { needed_s: 2.0, budget_s: 1.0 }.kind(),
            ServeError::RateLimited { retry_after_s: 0.5 }.kind(),
            ServeError::Brownout { retry_after_s: 2.0 }.kind(),
            ServeError::Cancelled.kind(),
            ServeError::ShuttingDown.kind(),
            ServeError::EngineDown.kind(),
            ServeError::Internal("x".into()).kind(),
        ];
        let set: std::collections::BTreeSet<_> = kinds.iter().collect();
        assert_eq!(set.len(), kinds.len());
    }

    #[test]
    fn rejections_map_to_rejected_finish() {
        assert_eq!(
            ServeError::QueueFull { inflight: 1, limit: 1 }.finish_reason(),
            FinishReason::Rejected
        );
        assert_eq!(
            ServeError::RateLimited { retry_after_s: 1.0 }.finish_reason(),
            FinishReason::Rejected
        );
        assert_eq!(
            ServeError::Brownout { retry_after_s: 2.0 }.finish_reason(),
            FinishReason::Rejected
        );
        assert_eq!(ServeError::ShuttingDown.finish_reason(), FinishReason::Rejected);
        assert_eq!(ServeError::Cancelled.finish_reason(), FinishReason::Cancelled);
        assert_eq!(ServeError::EngineDown.finish_reason(), FinishReason::Error);
    }
}
