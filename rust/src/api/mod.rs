//! Unified request-lifecycle serving API shared by both back-ends.
//!
//! EconoServe has two engines: the calibrated discrete-event simulator
//! (driven by [`crate::coordinator`]) and the real PJRT model server
//! ([`crate::server`]). Before this module existed they spoke different
//! dialects — the simulator's `Scheduler::plan(ctx) -> BatchPlan` seam
//! versus the real server's blocking submit/drain channels — so clients
//! could not stream tokens, cancel a request, or be load-shed, and the
//! paper's ordering policy only ran on the simulated path.
//!
//! This module defines the request lifecycle once, as a typed state
//! machine, and both engines implement it:
//!
//! ```text
//!                submit(SubmitOptions)
//!                        |
//!            AdmissionController::check
//!              /                    \
//!          Err(ServeError)        Ok(RequestHandle)
//!          [Rejected: 4xx/5xx]        |
//!                                  Queued ----cancel----> Finished(Cancelled)
//!                                     |
//!                        ordering::QueuePolicy picks
//!                                     |
//!                                  Running --per token--> StreamEvent::Token
//!                                   |   \----cancel/drop-> Finished(Cancelled)
//!                                   |
//!                        StreamEvent::Finished(Completion)
//!                        [Complete | LengthCap | Error]
//! ```
//!
//! The pieces:
//!  * [`SubmitOptions`] — everything a client states up front: prompt,
//!    token budget, predicted RL (for ordering), SLO budget, priority.
//!  * [`AdmissionController`] — the bounded front door: queue-depth and
//!    SLO-infeasibility shedding, shared by the HTTP server and the
//!    simulation coordinator (`run_admitted`).
//!  * [`RequestHandle`] — a channel-backed iterator of [`StreamEvent`]s:
//!    one [`TokenEvent`] per generated token, then a terminal
//!    [`Completion`] carrying the [`FinishReason`].
//!  * [`CancelToken`] — cooperative cancellation; the engine frees the
//!    request's decode slot at the next iteration boundary. Dropping the
//!    receiving half of a handle (e.g. an HTTP client disconnect) cancels
//!    implicitly; [`RequestHandle::detach`] opts out for fire-and-forget
//!    submission.
//!  * [`ServeError`] — the structured error taxonomy, each variant with a
//!    stable `kind()` string and an HTTP status mapping.
//!  * [`TokenBucketLimiter`] — deterministic per-key token-bucket rate
//!    limiting at the front door (`RateLimited` → 429 + Retry-After).
//!  * [`DrainGate`] — the graceful-shutdown gate: in-flight connections
//!    (token streams included) drain, new ones get 503 `shutting_down`.
//!
//! This module is engine-agnostic and std-only: it compiles (and is
//! tested) without the PJRT backend.

pub mod admission;
pub mod drain;
pub mod error;
pub mod rate_limit;
pub mod stream;
pub mod types;

pub use admission::{AdmissionConfig, AdmissionController};
pub use drain::{ConnGuard, DrainGate};
pub use error::ServeError;
pub use rate_limit::{RateLimitConfig, TokenBucketLimiter};
pub use stream::{channel, CancelToken, EventSink, RequestHandle};
pub use types::{Completion, FinishReason, StreamEvent, SubmitOptions, TokenEvent};
