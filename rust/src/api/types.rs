//! Request-lifecycle value types: what goes in ([`SubmitOptions`]), what
//! streams out ([`StreamEvent`]/[`TokenEvent`]), and how a request ends
//! ([`FinishReason`]/[`Completion`]).

/// Everything a client specifies when submitting one generation request.
///
/// The demo model has no tokenizer — callers supply token ids in
/// `[1, vocab)`.
#[derive(Debug, Clone)]
pub struct SubmitOptions {
    /// Prompt token ids.
    pub prompt: Vec<i32>,
    /// Stop after this many generated tokens (the trace's true RL).
    pub max_new_tokens: usize,
    /// Predicted response length, used by ordering (GT factors) and as
    /// the SLO-feasibility service estimate at admission; 0 = unknown
    /// (admission then assumes the full `max_new_tokens` budget).
    pub predicted_rl: u32,
    /// Seconds from submission to the JCT deadline (SLO); `INFINITY` =
    /// best-effort.
    pub slo_budget: f64,
    /// Explicit priority class, 0 = most urgent. Ranks above every other
    /// ordering factor (deadline slack, occupied KVC, length).
    pub priority: u8,
}

impl SubmitOptions {
    /// Best-effort request: no SLO, default priority, predicted RL taken
    /// from the token budget.
    pub fn new(prompt: Vec<i32>, max_new_tokens: usize) -> Self {
        SubmitOptions {
            prompt,
            max_new_tokens,
            predicted_rl: max_new_tokens as u32,
            slo_budget: f64::INFINITY,
            priority: 0,
        }
    }

    pub fn with_slo(mut self, budget_s: f64) -> Self {
        self.slo_budget = budget_s;
        self
    }

    pub fn with_predicted_rl(mut self, rl: u32) -> Self {
        self.predicted_rl = rl;
        self
    }

    pub fn with_priority(mut self, priority: u8) -> Self {
        self.priority = priority;
        self
    }
}

/// How a request's lifecycle ended.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    /// Generated its full `max_new_tokens` budget.
    Complete,
    /// Hit the engine's context-length cap before the token budget.
    LengthCap,
    /// Cancelled by the client (explicitly or by dropping the handle /
    /// connection) before completion.
    Cancelled,
    /// Shed by the [`super::AdmissionController`] — never serviced.
    Rejected,
    /// Engine-side failure.
    Error,
}

impl FinishReason {
    /// True for the terminal states that delivered a usable response.
    pub fn is_success(self) -> bool {
        matches!(self, FinishReason::Complete | FinishReason::LengthCap)
    }

    /// Stable wire name (HTTP responses, logs).
    pub fn as_str(self) -> &'static str {
        match self {
            FinishReason::Complete => "complete",
            FinishReason::LengthCap => "length_cap",
            FinishReason::Cancelled => "cancelled",
            FinishReason::Rejected => "rejected",
            FinishReason::Error => "error",
        }
    }
}

impl std::fmt::Display for FinishReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// One generated token, delivered as it is produced.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TokenEvent {
    /// 0-based index in the generated sequence (0 = the token the prefill
    /// itself emits, ORCA-style).
    pub index: u32,
    pub token: i32,
}

/// Terminal record of one request, with timing.
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: u64,
    pub finish: FinishReason,
    /// All tokens generated before the terminal state (partial output for
    /// `Cancelled`).
    pub tokens: Vec<i32>,
    /// Time to first token (s); 0 if none was produced.
    pub ttft_s: f64,
    /// Submission-to-terminal latency (s).
    pub latency_s: f64,
    /// Mean time between tokens (s).
    pub mean_tbt_s: f64,
    /// Finished successfully within its SLO budget.
    pub met_slo: bool,
}

/// What a [`super::RequestHandle`] yields: a stream of tokens, closed by
/// exactly one `Finished`.
#[derive(Debug, Clone)]
pub enum StreamEvent {
    Token(TokenEvent),
    Finished(Completion),
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finish_reason_success_and_names() {
        assert!(FinishReason::Complete.is_success());
        assert!(FinishReason::LengthCap.is_success());
        assert!(!FinishReason::Cancelled.is_success());
        assert!(!FinishReason::Rejected.is_success());
        assert!(!FinishReason::Error.is_success());
        assert_eq!(FinishReason::LengthCap.as_str(), "length_cap");
        assert_eq!(FinishReason::Cancelled.to_string(), "cancelled");
    }

    #[test]
    fn submit_options_builder() {
        let o = SubmitOptions::new(vec![1, 2, 3], 8).with_slo(2.5).with_priority(3);
        assert_eq!(o.prompt.len(), 3);
        assert_eq!(o.max_new_tokens, 8);
        assert_eq!(o.predicted_rl, 8);
        assert_eq!(o.slo_budget, 2.5);
        assert_eq!(o.priority, 3);
    }
}
