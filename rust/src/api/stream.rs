//! Channel-backed token streaming and cooperative cancellation.
//!
//! [`channel`] creates the two halves of one request's event stream: the
//! engine keeps the [`EventSink`] (inside its queue entry / decode slot)
//! and pushes a [`StreamEvent`] per generated token; the client keeps the
//! [`RequestHandle`] and consumes events as a blocking iterator.
//!
//! Cancellation is cooperative and flows both ways:
//!  * client → engine: [`RequestHandle::cancel`] (or any clone of its
//!    [`CancelToken`]) raises a flag the engine checks at every iteration
//!    boundary, freeing the decode slot mid-generation;
//!  * implicit: if the receiving half is dropped (an HTTP client
//!    disconnect), the engine's next `send_token` fails and the request
//!    is treated as cancelled — unless the handle was [`detach`]ed
//!    first, which marks the request fire-and-forget.
//!
//! [`detach`]: RequestHandle::detach

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};

use super::error::ServeError;
use super::types::{Completion, StreamEvent, TokenEvent};

/// Shared lifecycle flags between the handle and the engine sink.
#[derive(Debug, Default)]
struct Flags {
    cancelled: AtomicBool,
    detached: AtomicBool,
}

/// Cloneable cancellation signal for one request. Cheap to clone and
/// `Send`, so a watchdog thread (or an HTTP connection handler) can
/// cancel while another thread consumes the stream.
#[derive(Debug, Clone)]
pub struct CancelToken(Arc<Flags>);

impl CancelToken {
    pub fn cancel(&self) {
        self.0.cancelled.store(true, Ordering::Release);
    }

    pub fn is_cancelled(&self) -> bool {
        self.0.cancelled.load(Ordering::Acquire)
    }
}

/// Create the event stream for one request: engine-side sink + client
/// handle.
pub fn channel(id: u64) -> (EventSink, RequestHandle) {
    let flags = Arc::new(Flags::default());
    let (tx, rx) = mpsc::channel();
    (
        EventSink { tx, flags: flags.clone() },
        RequestHandle { id, rx, flags },
    )
}

/// Engine-side half: pushes events toward the client.
pub struct EventSink {
    tx: mpsc::Sender<StreamEvent>,
    flags: Arc<Flags>,
}

impl EventSink {
    /// True once the client cancelled; the engine should free the slot at
    /// the next iteration boundary.
    pub fn cancelled(&self) -> bool {
        self.flags.cancelled.load(Ordering::Acquire)
    }

    /// Deliver one token. Returns `false` when the client is gone (the
    /// receiving half was dropped without `detach`), which the engine
    /// must treat as a cancellation; the flag is raised as a side effect
    /// so subsequent `cancelled()` checks agree.
    pub fn send_token(&self, index: u32, token: i32) -> bool {
        if self.tx.send(StreamEvent::Token(TokenEvent { index, token })).is_ok() {
            return true;
        }
        if self.flags.detached.load(Ordering::Acquire) {
            return true; // fire-and-forget: discard tokens, keep generating
        }
        self.flags.cancelled.store(true, Ordering::Release);
        false
    }

    /// Deliver the terminal event. Send failures are ignored: a departed
    /// client cannot observe its own completion.
    pub fn finish(&self, completion: Completion) {
        let _ = self.tx.send(StreamEvent::Finished(completion));
    }
}

/// Client-side half: a channel-backed iterator over one request's
/// [`StreamEvent`]s. The stream ends with exactly one
/// [`StreamEvent::Finished`]; iteration then yields `None` once the
/// engine releases its sink.
pub struct RequestHandle {
    id: u64,
    rx: mpsc::Receiver<StreamEvent>,
    flags: Arc<Flags>,
}

impl RequestHandle {
    /// Engine-assigned request id.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Request cancellation; the engine frees the request's slot at the
    /// next iteration boundary and finishes the stream with
    /// `FinishReason::Cancelled`.
    pub fn cancel(&self) {
        self.flags.cancelled.store(true, Ordering::Release);
    }

    /// A cloneable cancellation token for cross-thread cancellation.
    pub fn cancel_token(&self) -> CancelToken {
        CancelToken(self.flags.clone())
    }

    /// Blocking receive of the next event; `None` when the stream ended.
    pub fn recv(&self) -> Option<StreamEvent> {
        self.rx.recv().ok()
    }

    /// Non-blocking receive.
    pub fn try_recv(&self) -> Option<StreamEvent> {
        self.rx.try_recv().ok()
    }

    /// Mark the request fire-and-forget and drop the receiving half:
    /// generation continues, tokens are discarded, and the completion is
    /// recorded engine-side only.
    pub fn detach(self) {
        self.flags.detached.store(true, Ordering::Release);
    }

    /// Block until the terminal event and return it, discarding token
    /// events (the [`Completion`] carries the full token list anyway).
    /// `Err(EngineDown)` if the engine died without finishing the stream.
    pub fn wait(self) -> Result<Completion, ServeError> {
        loop {
            match self.rx.recv() {
                Ok(StreamEvent::Finished(c)) => return Ok(c),
                Ok(StreamEvent::Token(_)) => continue,
                Err(_) => return Err(ServeError::EngineDown),
            }
        }
    }
}

impl Iterator for RequestHandle {
    type Item = StreamEvent;

    fn next(&mut self) -> Option<StreamEvent> {
        self.rx.recv().ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::types::FinishReason;

    fn completion(id: u64, finish: FinishReason) -> Completion {
        Completion {
            id,
            finish,
            tokens: vec![7],
            ttft_s: 0.01,
            latency_s: 0.02,
            mean_tbt_s: 0.005,
            met_slo: finish.is_success(),
        }
    }

    #[test]
    fn tokens_then_finish_flow_through() {
        let (sink, handle) = channel(3);
        assert!(sink.send_token(0, 11));
        assert!(sink.send_token(1, 12));
        sink.finish(completion(3, FinishReason::Complete));
        drop(sink);
        let events: Vec<StreamEvent> = handle.collect();
        assert_eq!(events.len(), 3);
        match &events[0] {
            StreamEvent::Token(t) => assert_eq!((t.index, t.token), (0, 11)),
            other => panic!("expected token, got {other:?}"),
        }
        match &events[2] {
            StreamEvent::Finished(c) => assert_eq!(c.finish, FinishReason::Complete),
            other => panic!("expected finish, got {other:?}"),
        }
    }

    #[test]
    fn cancel_is_visible_to_sink_from_any_clone() {
        let (sink, handle) = channel(1);
        assert!(!sink.cancelled());
        let token = handle.cancel_token();
        token.cancel();
        assert!(sink.cancelled());
        assert!(token.is_cancelled());
    }

    #[test]
    fn dropped_handle_cancels_on_next_send() {
        let (sink, handle) = channel(1);
        drop(handle);
        assert!(!sink.cancelled(), "drop alone is not observed until a send");
        assert!(!sink.send_token(0, 5), "send to a dropped handle must fail");
        assert!(sink.cancelled(), "failed send raises the cancel flag");
    }

    #[test]
    fn detached_handle_does_not_cancel() {
        let (sink, handle) = channel(1);
        handle.detach();
        assert!(sink.send_token(0, 5), "detached: send failures are ignored");
        assert!(!sink.cancelled());
    }

    #[test]
    fn wait_returns_completion_or_engine_down() {
        let (sink, handle) = channel(9);
        sink.send_token(0, 1);
        sink.finish(completion(9, FinishReason::LengthCap));
        let c = handle.wait().unwrap();
        assert_eq!(c.id, 9);
        assert_eq!(c.finish, FinishReason::LengthCap);

        let (sink2, handle2) = channel(10);
        drop(sink2); // engine died without finishing
        assert!(matches!(handle2.wait(), Err(ServeError::EngineDown)));
    }
}
