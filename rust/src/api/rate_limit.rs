//! Per-key token-bucket rate limiting for the serving front door.
//!
//! Classic token bucket with continuous refill: each key (API key, or
//! `"anon"` for unidentified clients) owns a bucket of capacity `burst`
//! refilled at `rate_per_s` tokens per second. A request costs one
//! token; an empty bucket means HTTP 429 with a `Retry-After` hint of
//! exactly how long until one token has accumulated.
//!
//! The math is deterministic: the caller passes `now_s` (monotonic
//! seconds from any epoch), so tests drive the clock explicitly and the
//! refill arithmetic is a pure function of the call sequence. Keys are
//! tracked in a `BTreeMap` — a handful of API keys, not an unbounded
//! cardinality — and a bucket is created full on first sight.

use std::collections::BTreeMap;

/// Rate-limiter knobs. The default is **off** (`rate_per_s == 0.0`):
/// serving behaves exactly as before unless a limit is configured.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RateLimitConfig {
    /// Sustained request rate per key, per second. `<= 0` disables the
    /// limiter entirely.
    pub rate_per_s: f64,
    /// Bucket capacity: how many requests a key may burst above the
    /// sustained rate. Clamped to at least 1 when the limiter is on.
    pub burst: f64,
}

impl Default for RateLimitConfig {
    fn default() -> Self {
        RateLimitConfig { rate_per_s: 0.0, burst: 1.0 }
    }
}

impl RateLimitConfig {
    /// An enabled limiter: `rate_per_s` sustained, `burst` capacity.
    pub fn per_key(rate_per_s: f64, burst: f64) -> Self {
        RateLimitConfig { rate_per_s, burst }
    }

    pub fn enabled(&self) -> bool {
        self.rate_per_s > 0.0
    }
}

#[derive(Debug, Clone, Copy)]
struct BucketState {
    /// Tokens available at `last_s`.
    tokens: f64,
    /// Clock of the last refill.
    last_s: f64,
}

/// The limiter itself. Not internally synchronized — the HTTP layer
/// wraps it in a `Mutex` (admission is a single fast check, not a hot
/// loop).
#[derive(Debug)]
pub struct TokenBucketLimiter {
    cfg: RateLimitConfig,
    keys: BTreeMap<String, BucketState>,
}

impl TokenBucketLimiter {
    pub fn new(cfg: RateLimitConfig) -> Self {
        TokenBucketLimiter { cfg, keys: BTreeMap::new() }
    }

    pub fn config(&self) -> RateLimitConfig {
        self.cfg
    }

    /// Try to spend one token from `key`'s bucket at time `now_s`.
    /// `Ok(())` admits the request; `Err(retry_after_s)` is the exact
    /// time until the bucket next holds a full token.
    ///
    /// A non-monotonic `now_s` (clock going backwards) refills nothing
    /// but never *removes* accumulated tokens.
    pub fn check(&mut self, key: &str, now_s: f64) -> Result<(), f64> {
        if !self.cfg.enabled() {
            return Ok(());
        }
        let burst = self.cfg.burst.max(1.0);
        let rate = self.cfg.rate_per_s;
        let b = self
            .keys
            .entry(key.to_string())
            .or_insert(BucketState { tokens: burst, last_s: now_s });
        let dt = (now_s - b.last_s).max(0.0);
        b.tokens = (b.tokens + dt * rate).min(burst);
        b.last_s = b.last_s.max(now_s);
        if b.tokens >= 1.0 {
            b.tokens -= 1.0;
            Ok(())
        } else {
            Err((1.0 - b.tokens) / rate)
        }
    }

    /// Distinct keys seen so far (diagnostics).
    pub fn key_count(&self) -> usize {
        self.keys.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_limiter_admits_everything() {
        let mut l = TokenBucketLimiter::new(RateLimitConfig::default());
        for i in 0..1000 {
            assert!(l.check("anon", i as f64 * 1e-6).is_ok());
        }
        assert_eq!(l.key_count(), 0, "disabled limiter tracks no state");
    }

    #[test]
    fn refill_math_is_deterministic() {
        // 2 tokens/s, burst 4: drain the burst, then the bucket refills
        // exactly one token per 0.5 s.
        let mut l = TokenBucketLimiter::new(RateLimitConfig::per_key(2.0, 4.0));
        for _ in 0..4 {
            assert!(l.check("k", 0.0).is_ok());
        }
        // Empty: retry hint is exactly 1 token / (2 tokens/s).
        assert_eq!(l.check("k", 0.0), Err(0.5));
        // 0.25 s later: half a token in the bucket, 0.25 s to a whole one.
        assert_eq!(l.check("k", 0.25), Err(0.25));
        // 0.5 s from the drain: exactly one token has accumulated.
        assert!(l.check("k", 0.5).is_ok());
        assert!(l.check("k", 0.5).is_err());
    }

    #[test]
    fn burst_cap_bounds_idle_accumulation() {
        let mut l = TokenBucketLimiter::new(RateLimitConfig::per_key(1.0, 3.0));
        assert!(l.check("k", 0.0).is_ok());
        // A very long idle stretch refills to the cap, not beyond: only
        // `burst` requests pass back-to-back.
        for _ in 0..3 {
            assert!(l.check("k", 1e6).is_ok());
        }
        assert!(l.check("k", 1e6).is_err());
    }

    #[test]
    fn keys_are_isolated() {
        let mut l = TokenBucketLimiter::new(RateLimitConfig::per_key(1.0, 1.0));
        assert!(l.check("alice", 0.0).is_ok());
        assert!(l.check("alice", 0.0).is_err());
        // Bob's bucket is untouched by Alice's spend.
        assert!(l.check("bob", 0.0).is_ok());
        assert!(l.check("bob", 0.0).is_err());
        assert_eq!(l.key_count(), 2);
    }

    #[test]
    fn backwards_clock_neither_refills_nor_steals() {
        let mut l = TokenBucketLimiter::new(RateLimitConfig::per_key(1.0, 2.0));
        assert!(l.check("k", 10.0).is_ok());
        // now_s jumps backwards: dt clamps to 0, the remaining token is
        // still spendable and last_s stays at its high-water mark.
        assert!(l.check("k", 3.0).is_ok());
        assert!(l.check("k", 3.0).is_err());
        // Refill resumes from 10.0, not 3.0: at 10.5 half a token.
        assert_eq!(l.check("k", 10.5), Err(0.5));
    }
}
