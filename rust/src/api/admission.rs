//! Admission control: the bounded front door both serving paths share.
//!
//! Production SLO-aware systems shed load at admission rather than let an
//! unbounded queue convert overload into universal deadline misses. The
//! controller applies, in order:
//!  1. request validation (non-empty prompt, positive token budget,
//!     prompt within the prefill window) — HTTP 400;
//!  2. a bound on requests in flight (queued + executing) — HTTP 429;
//!  3. SLO-infeasibility: a request whose budget is below its best-case
//!     service time on an idle engine can never meet its deadline, so
//!     admitting it only burns capacity other requests could use —
//!     HTTP 503.

use super::error::ServeError;
use super::types::SubmitOptions;

/// Front-door limits. `Copy` so experiment drivers can embed it in their
/// run configs.
#[derive(Debug, Clone, Copy)]
pub struct AdmissionConfig {
    /// Maximum requests in flight (waiting + executing); 0 = unbounded.
    pub max_inflight: usize,
    /// Longest admissible prompt in tokens; 0 = no check (the engine
    /// substitutes its prefill window when it builds the controller).
    pub max_prompt: usize,
    /// Estimated seconds per generated token on an otherwise idle engine,
    /// used for the SLO-infeasibility check; 0 disables it.
    pub est_token_time: f64,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig { max_inflight: 256, max_prompt: 0, est_token_time: 0.0 }
    }
}

/// Stateless admission decisions over an [`AdmissionConfig`].
#[derive(Debug, Clone)]
pub struct AdmissionController {
    cfg: AdmissionConfig,
}

impl AdmissionController {
    pub fn new(cfg: AdmissionConfig) -> Self {
        AdmissionController { cfg }
    }

    pub fn config(&self) -> &AdmissionConfig {
        &self.cfg
    }

    /// Low-level decision from request shape alone — the simulation path
    /// uses this directly since it has no `SubmitOptions`.
    /// `slo_budget` is seconds of slack until the deadline.
    pub fn decide(
        &self,
        inflight: usize,
        prompt_len: usize,
        max_new_tokens: usize,
        slo_budget: f64,
    ) -> Result<(), ServeError> {
        if prompt_len == 0 {
            return Err(ServeError::InvalidRequest("'prompt' must be non-empty".into()));
        }
        if max_new_tokens == 0 {
            return Err(ServeError::InvalidRequest("'max_new_tokens' must be >= 1".into()));
        }
        if self.cfg.max_prompt > 0 && prompt_len > self.cfg.max_prompt {
            return Err(ServeError::PromptTooLong { len: prompt_len, max: self.cfg.max_prompt });
        }
        if self.cfg.max_inflight > 0 && inflight >= self.cfg.max_inflight {
            return Err(ServeError::QueueFull { inflight, limit: self.cfg.max_inflight });
        }
        if self.cfg.est_token_time > 0.0 && slo_budget.is_finite() {
            let needed_s = max_new_tokens as f64 * self.cfg.est_token_time;
            if needed_s > slo_budget {
                return Err(ServeError::SloInfeasible { needed_s, budget_s: slo_budget });
            }
        }
        Ok(())
    }

    /// Admission decision for one submission given the engine's current
    /// in-flight count. The SLO-feasibility estimate uses the client's
    /// predicted RL when provided (the budget cap is only an upper
    /// bound on the true response length), falling back to the budget.
    pub fn check(&self, inflight: usize, opts: &SubmitOptions) -> Result<(), ServeError> {
        let est_tokens = if opts.predicted_rl > 0 {
            (opts.predicted_rl as usize).min(opts.max_new_tokens)
        } else {
            opts.max_new_tokens
        };
        // Shape validation still uses the real token budget (a zero
        // budget is invalid regardless of the prediction).
        if opts.max_new_tokens == 0 {
            return Err(ServeError::InvalidRequest("'max_new_tokens' must be >= 1".into()));
        }
        self.decide(inflight, opts.prompt.len(), est_tokens, opts.slo_budget)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctl(max_inflight: usize, max_prompt: usize, est: f64) -> AdmissionController {
        AdmissionController::new(AdmissionConfig {
            max_inflight,
            max_prompt,
            est_token_time: est,
        })
    }

    #[test]
    fn validates_request_shape() {
        let c = ctl(8, 16, 0.0);
        assert!(matches!(
            c.decide(0, 0, 4, f64::INFINITY),
            Err(ServeError::InvalidRequest(_))
        ));
        assert!(matches!(
            c.decide(0, 4, 0, f64::INFINITY),
            Err(ServeError::InvalidRequest(_))
        ));
        assert!(matches!(
            c.decide(0, 17, 4, f64::INFINITY),
            Err(ServeError::PromptTooLong { len: 17, max: 16 })
        ));
        assert!(c.decide(0, 16, 4, f64::INFINITY).is_ok());
    }

    #[test]
    fn bounds_inflight_queue() {
        let c = ctl(2, 0, 0.0);
        assert!(c.decide(0, 4, 4, f64::INFINITY).is_ok());
        assert!(c.decide(1, 4, 4, f64::INFINITY).is_ok());
        assert!(matches!(
            c.decide(2, 4, 4, f64::INFINITY),
            Err(ServeError::QueueFull { inflight: 2, limit: 2 })
        ));
        // Unbounded when limit is 0.
        assert!(ctl(0, 0, 0.0).decide(10_000, 4, 4, f64::INFINITY).is_ok());
    }

    #[test]
    fn sheds_infeasible_slo() {
        let c = ctl(0, 0, 0.01); // 10 ms/token best case
        // 100 tokens need >= 1 s; a 0.5 s budget can never be met.
        assert!(matches!(
            c.decide(0, 4, 100, 0.5),
            Err(ServeError::SloInfeasible { .. })
        ));
        assert!(c.decide(0, 4, 100, 2.0).is_ok());
        // Best-effort requests (infinite budget) are never shed on SLO.
        assert!(c.decide(0, 4, 100, f64::INFINITY).is_ok());
    }

    #[test]
    fn check_uses_submit_options() {
        use crate::api::types::SubmitOptions;
        let c = ctl(1, 8, 0.0);
        let opts = SubmitOptions::new(vec![1, 2], 4);
        assert!(c.check(0, &opts).is_ok());
        assert!(matches!(c.check(1, &opts), Err(ServeError::QueueFull { .. })));
    }

    #[test]
    fn check_prefers_predicted_rl_for_slo_estimate() {
        use crate::api::types::SubmitOptions;
        let c = ctl(0, 0, 0.01); // 10 ms/token best case
        // Budget cap says 400 tokens (4 s best case), prediction says 20
        // (0.2 s): a 1 s SLO is feasible under the prediction.
        let opts = SubmitOptions::new(vec![1], 400).with_predicted_rl(20).with_slo(1.0);
        assert!(c.check(0, &opts).is_ok());
        // Without a prediction the full budget is assumed and the same
        // SLO is shed as infeasible.
        let opts = SubmitOptions::new(vec![1], 400).with_predicted_rl(0).with_slo(1.0);
        assert!(matches!(c.check(0, &opts), Err(ServeError::SloInfeasible { .. })));
    }
}
