//! Graceful-shutdown gate: count connections in, drain them out.
//!
//! The HTTP acceptor holds a [`DrainGate`]; every accepted connection
//! must [`DrainGate::try_enter`] before being served. While the gate is
//! open this hands back a [`ConnGuard`] whose `Drop` decrements the
//! active count; once [`DrainGate::begin_drain`] fires, `try_enter`
//! returns `None` (the acceptor answers 503 `shutting_down`) while
//! already-admitted connections — including long-lived token streams —
//! run to completion. [`DrainGate::wait_idle`] blocks the shutdown path
//! until the last guard drops (or a deadline passes, for crash-only
//! exits).
//!
//! std-only: a `Mutex<State>` + `Condvar`, no async runtime.

use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

#[derive(Debug, Default)]
struct State {
    draining: bool,
    active: usize,
}

/// Shared connection gate (one per server, cloned via `Arc`).
#[derive(Debug, Default)]
pub struct DrainGate {
    state: Mutex<State>,
    idle: Condvar,
}

impl DrainGate {
    pub fn new() -> Arc<Self> {
        Arc::new(DrainGate::default())
    }

    /// Admit one connection: `Some(guard)` while serving, `None` once
    /// draining has begun. The guard's `Drop` releases the slot.
    pub fn try_enter(self: &Arc<Self>) -> Option<ConnGuard> {
        let mut s = crate::util::sync::lock(&self.state);
        if s.draining {
            return None;
        }
        s.active += 1;
        Some(ConnGuard { gate: Arc::clone(self) })
    }

    /// Flip to draining: subsequent `try_enter` calls fail, existing
    /// guards are unaffected. Idempotent.
    pub fn begin_drain(&self) {
        let mut s = crate::util::sync::lock(&self.state);
        s.draining = true;
        // An already-idle server must not hang in wait_idle.
        self.idle.notify_all();
    }

    pub fn is_draining(&self) -> bool {
        crate::util::sync::lock(&self.state).draining
    }

    /// Connections currently inside the gate.
    pub fn active(&self) -> usize {
        crate::util::sync::lock(&self.state).active
    }

    /// Block until every admitted connection has finished, or `timeout`
    /// elapses. Returns `true` on a clean drain (no connections left).
    pub fn wait_idle(&self, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        let mut s = crate::util::sync::lock(&self.state);
        while s.active > 0 {
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                return false;
            }
            let (next, timed_out) = crate::util::sync::wait_timeout(&self.idle, s, left);
            s = next;
            if timed_out && s.active > 0 {
                return false;
            }
        }
        true
    }
}

/// RAII token for one admitted connection.
#[derive(Debug)]
pub struct ConnGuard {
    gate: Arc<DrainGate>,
}

impl Drop for ConnGuard {
    fn drop(&mut self) {
        let mut s = crate::util::sync::lock(&self.gate.state);
        s.active = s.active.saturating_sub(1);
        if s.active == 0 {
            self.gate.idle.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn enter_then_drain_refuses_new_but_keeps_existing() {
        let gate = DrainGate::new();
        let g1 = gate.try_enter().expect("open gate admits");
        assert_eq!(gate.active(), 1);
        gate.begin_drain();
        assert!(gate.is_draining());
        assert!(gate.try_enter().is_none(), "draining gate refuses new connections");
        // The in-flight connection is still counted until it finishes.
        assert_eq!(gate.active(), 1);
        drop(g1);
        assert_eq!(gate.active(), 0);
    }

    #[test]
    fn wait_idle_blocks_until_last_guard_drops() {
        let gate = DrainGate::new();
        let guard = gate.try_enter().unwrap();
        gate.begin_drain();
        let waiter = {
            let gate = Arc::clone(&gate);
            thread::spawn(move || gate.wait_idle(Duration::from_secs(5)))
        };
        // Simulate an in-flight stream finishing shortly after drain.
        thread::sleep(Duration::from_millis(20));
        drop(guard);
        assert!(waiter.join().unwrap(), "drain completes once the stream ends");
    }

    #[test]
    fn wait_idle_times_out_on_a_stuck_connection() {
        let gate = DrainGate::new();
        let _stuck = gate.try_enter().unwrap();
        gate.begin_drain();
        assert!(!gate.wait_idle(Duration::from_millis(30)));
        assert_eq!(gate.active(), 1);
    }

    #[test]
    fn idle_drain_returns_immediately() {
        let gate = DrainGate::new();
        gate.begin_drain();
        assert!(gate.wait_idle(Duration::from_millis(1)));
    }

    #[test]
    fn drain_is_idempotent_and_guard_is_send() {
        fn assert_send<T: Send>() {}
        assert_send::<ConnGuard>();
        let gate = DrainGate::new();
        gate.begin_drain();
        gate.begin_drain();
        assert!(gate.try_enter().is_none());
    }
}
