//! Response-length (RL) prediction (§2.3, §3.3.2).
//!
//! The paper fine-tunes OPT-13B with LoRA to predict a request's RL from
//! its prompt, reporting 77.5% / 73.2% / 69.8% accuracy at the per-trace
//! sweet-spot padding ratios and the under/over-provision splits of
//! Fig 5a. That model (and its GPUs) are not available here, so
//! [`SimPredictor`] reproduces the predictor's *error process*: a
//! multiplicative log-normal error around the true RL with per-trace
//! sigma calibrated so that, after sweet-spot padding, the fraction of
//! under-provisioned requests matches Fig 5a:
//!
//! ```text
//! P(under) = P(pred * (1+pad) < true) = Phi(-ln(1+pad) / sigma)
//! alpaca:     9.30% under @ pad 0.10  => sigma ~ 0.072
//! sharegpt:  13.42% under @ pad 0.15  => sigma ~ 0.127
//! bookcorpus:21.92% under @ pad 0.20  => sigma ~ 0.235
//! ```
//!
//! Predictions are quantized up to the KVC block size: allocation is
//! block-granular anyway, and quantization is what makes same-RL GT
//! groups (Fig 2) non-trivial. [`OraclePredictor`] returns the truth
//! (the paper's *Oracle* variant).

pub mod faults;

use crate::core::ReqId;
use crate::util::rng::Rng;

/// A raw RL prediction for one request (pre-padding).
pub trait Predictor: Send {
    /// Predict the response length for request `id` whose true RL is
    /// `true_rl`. Implementations must be deterministic per (seed, id).
    fn predict_raw(&mut self, id: ReqId, true_rl: u32) -> u32;

    /// Request context for the next `predict_raw` call: the simulated
    /// time of the prediction and the request's prompt length. The world
    /// calls this before every (re-)prediction; the fault wrapper
    /// ([`faults::FaultyPredictor`]) uses it to evaluate its episode
    /// timeline and to build the outage fallback estimate. Plain
    /// predictors ignore it.
    fn observe_request(&mut self, _now: f64, _prompt_len: u32) {}

    /// Latency of one prediction (the paper measures ~0.921 s on its
    /// separate 4-GPU predictor server; overlapped with queueing/prefill).
    fn latency(&self) -> f64 {
        0.0
    }

    /// Accuracy accounting `(n_pred, n_close)`: total predictions made
    /// and those within one quantum of the quantized truth. `(0, 0)` for
    /// predictors that do not track it (the oracle is always exact).
    fn accuracy(&self) -> (u64, u64) {
        (0, 0)
    }

    fn name(&self) -> &'static str;
}

/// Log-normal-error predictor calibrated per trace.
pub struct SimPredictor {
    sigma: f64,
    /// Multiplicative bias (1.0 = unbiased in log space).
    bias: f64,
    quantum: u32,
    latency: f64,
    rng: Rng,
    /// Accuracy accounting: predictions within +/-1 quantum of truth.
    pub n_pred: u64,
    pub n_close: u64,
}

impl SimPredictor {
    pub fn new(sigma: f64, quantum: u32, seed: u64) -> Self {
        SimPredictor {
            sigma,
            bias: 1.0,
            quantum: quantum.max(1),
            latency: 0.921,
            rng: Rng::new(seed ^ 0x9E1D),
            n_pred: 0,
            n_close: 0,
        }
    }

    /// Per-trace calibration (see module docs).
    pub fn for_trace(trace: &str, quantum: u32, seed: u64) -> Self {
        let sigma = match trace {
            "alpaca" => 0.072,
            "sharegpt" => 0.127,
            "bookcorpus" => 0.235,
            _ => 0.15,
        };
        Self::new(sigma, quantum, seed)
    }

    /// Set the multiplicative bias (`SystemConfig::predictor_bias`):
    /// `< 1` systematically under-predicts, `> 1` over-predicts.
    pub fn with_bias(mut self, bias: f64) -> Self {
        debug_assert!(bias > 0.0, "predictor bias must be positive: {bias}");
        self.bias = bias;
        self
    }

    fn quantize(&self, x: f64) -> u32 {
        let q = self.quantum as f64;
        ((x / q).ceil() * q).max(q) as u32
    }
}

impl Predictor for SimPredictor {
    fn predict_raw(&mut self, _id: ReqId, true_rl: u32) -> u32 {
        let noise = (self.rng.normal() * self.sigma).exp() * self.bias;
        let pred = self.quantize(true_rl as f64 * noise);
        self.n_pred += 1;
        if pred.abs_diff(self.quantize(true_rl as f64)) <= self.quantum {
            self.n_close += 1;
        }
        pred
    }

    fn latency(&self) -> f64 {
        self.latency
    }

    fn accuracy(&self) -> (u64, u64) {
        (self.n_pred, self.n_close)
    }

    fn name(&self) -> &'static str {
        "sim-lora"
    }
}

/// Perfect predictor (the paper's Oracle upper bound).
pub struct OraclePredictor {
    quantum: u32,
}

impl OraclePredictor {
    pub fn new(quantum: u32) -> Self {
        OraclePredictor { quantum: quantum.max(1) }
    }
}

impl Predictor for OraclePredictor {
    fn predict_raw(&mut self, _id: ReqId, true_rl: u32) -> u32 {
        let q = self.quantum;
        true_rl.div_ceil(q) * q
    }

    fn name(&self) -> &'static str {
        "oracle"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oracle_quantizes_up() {
        let mut o = OraclePredictor::new(32);
        assert_eq!(o.predict_raw(0, 1), 32);
        assert_eq!(o.predict_raw(0, 32), 32);
        assert_eq!(o.predict_raw(0, 33), 64);
    }

    #[test]
    fn sim_predictor_underprovision_rate_matches_calibration() {
        // With sigma=0.127 and padding 15%, ~13.4% of requests should be
        // under-provisioned (padded prediction below truth).
        let mut p = SimPredictor::for_trace("sharegpt", 1, 7);
        let pad = 1.15;
        let true_rl = 300u32;
        let n = 100_000;
        let mut under = 0;
        for i in 0..n {
            let pred = p.predict_raw(i, true_rl);
            if (pred as f64 * pad) < true_rl as f64 {
                under += 1;
            }
        }
        let frac = under as f64 / n as f64;
        assert!((0.10..0.17).contains(&frac), "under-provision frac {frac}");
    }

    #[test]
    fn bias_shifts_predictions_multiplicatively() {
        // Same seed, bias 0.5 vs unbiased: the biased predictor's mean
        // prediction should sit near half the unbiased one.
        let mut plain = SimPredictor::new(0.05, 1, 99);
        let mut biased = SimPredictor::new(0.05, 1, 99).with_bias(0.5);
        let (mut sum_p, mut sum_b) = (0u64, 0u64);
        for i in 0..2000 {
            sum_p += plain.predict_raw(i, 400) as u64;
            sum_b += biased.predict_raw(i, 400) as u64;
        }
        let ratio = sum_b as f64 / sum_p as f64;
        assert!((ratio - 0.5).abs() < 0.02, "bias ratio {ratio}");
        // A strong bias destroys closeness accounting.
        let (n, close) = biased.accuracy();
        assert_eq!(n, 2000);
        assert_eq!(close, 0, "bias 0.5 should never land within one quantum");
    }

    #[test]
    fn predictions_quantized() {
        let mut p = SimPredictor::new(0.1, 32, 1);
        for i in 0..100 {
            let v = p.predict_raw(i, 100);
            assert_eq!(v % 32, 0);
            assert!(v >= 32);
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let mut a = SimPredictor::new(0.2, 32, 5);
        let mut b = SimPredictor::new(0.2, 32, 5);
        for i in 0..50 {
            assert_eq!(a.predict_raw(i, 123), b.predict_raw(i, 123));
        }
    }

    #[test]
    fn grouping_exists_after_quantization() {
        // Fig 2 precondition: quantized predictions collide often enough
        // to form same-RL groups.
        let mut p = SimPredictor::for_trace("sharegpt", 32, 11);
        let mut rng = Rng::new(3);
        let mut counts = std::collections::HashMap::new();
        for i in 0..1000 {
            let true_rl = (rng.log_normal(5.5, 0.7)).clamp(19.0, 991.0) as u32;
            let v = p.predict_raw(i, true_rl);
            *counts.entry(v).or_insert(0u32) += 1;
        }
        let multi = counts.values().filter(|c| **c >= 4).count();
        assert!(multi >= 5, "expected many groups with >=4 members, got {multi}");
    }
}
