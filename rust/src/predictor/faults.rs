//! Deterministic predictor fault injection: chaos profiles for the RL
//! prediction resource (the robustness mirror of `fleet::faults`).
//!
//! EconoServe reserves KVC up-front for the *predicted* response length
//! (§2.3, §3.3.2), which makes the predictor a single point of failure:
//! a drifting, heavy-tailed, stale, or unavailable predictor turns §3.2
//! pipelining into an eviction storm. A [`PredictorFaultProfile`] names
//! a reproducible degradation scenario; [`FaultyPredictor`] applies it
//! as a composable wrapper over any inner [`Predictor`]:
//!
//!  * **bias-drift** — jittered-periodic episodes during which every
//!    prediction is scaled by a factor sampled from a low band (the
//!    dangerous, under-predicting direction: calibration decays between
//!    retrains).
//!  * **heavy-tail** — per-prediction chance of a blunder: the estimate
//!    is multiplied or divided by a large factor with equal odds (the
//!    error distribution grows the tails a log-normal lacks).
//!  * **regime-shift** — step episodes where the workload's length
//!    regime moved but the predictor did not: predictions scale by a
//!    fixed stale-model factor for the episode.
//!  * **outage** — the predictor server is unreachable for a window; the
//!    wrapper falls back to a conservative prompt-proportional estimate
//!    (long prompts tend to long answers; over-provisioning beats
//!    triggering eviction cascades).
//!  * **full-chaos** — all of the above at moderated rates.
//!
//! Episode timelines draw from a dedicated RNG stream
//! (`stream::PREDICTOR` off the per-world seed), so they are pure
//! functions of (profile, seed) — enabling predictor chaos never
//! perturbs the workload, router, replica-fault, or guardrail draws, and
//! runs are bit-identical at any thread count (pinned in
//! tests/equivalence.rs).

use crate::core::ReqId;
use crate::util::rng::{derive_seed, Rng};

use super::Predictor;

/// One named predictor degradation scenario. Fields with `every == 0`
/// (or `tail_prob == 0`) disable that fault process entirely — its RNG
/// sub-stream is never consumed, so `none` is exactly a no-op.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PredictorFaultProfile {
    pub name: &'static str,
    /// Mean seconds between bias-drift episodes (0 = never).
    pub drift_every: f64,
    /// Length of one drift episode (seconds).
    pub drift_len: f64,
    /// Multiplicative bias band `[lo, hi]` sampled once per episode.
    pub drift_lo: f64,
    pub drift_hi: f64,
    /// Per-prediction probability of a heavy-tail blunder (0 = never).
    pub tail_prob: f64,
    /// Blunder magnitude: the prediction is multiplied or divided by
    /// this factor with equal odds.
    pub tail_factor: f64,
    /// Mean seconds between regime-shift episodes (0 = never).
    pub shift_every: f64,
    /// Length of one shift episode (seconds).
    pub shift_len: f64,
    /// Stale-model scale applied to predictions during a shift.
    pub shift_factor: f64,
    /// Mean seconds between predictor outages (0 = never).
    pub outage_every: f64,
    /// Length of one outage window (seconds).
    pub outage_len: f64,
    /// Outage fallback: estimate = `prompt_len * fallback_scale`
    /// (quantized up), deliberately conservative.
    pub fallback_scale: f64,
}

impl PredictorFaultProfile {
    /// Whether this profile injects anything at all. The harness only
    /// wraps the inner predictor when active, so `none` runs are
    /// bit-identical to builds without this module.
    pub fn is_active(&self) -> bool {
        self.drift_every > 0.0
            || self.tail_prob > 0.0
            || self.shift_every > 0.0
            || self.outage_every > 0.0
    }
}

const NONE: PredictorFaultProfile = PredictorFaultProfile {
    name: "none",
    drift_every: 0.0,
    drift_len: 0.0,
    drift_lo: 1.0,
    drift_hi: 1.0,
    tail_prob: 0.0,
    tail_factor: 1.0,
    shift_every: 0.0,
    shift_len: 0.0,
    shift_factor: 1.0,
    outage_every: 0.0,
    outage_len: 0.0,
    fallback_scale: 2.0,
};

/// The profile registry (`--predictor-faults` on the CLI and the
/// `predictor_faults` grid axis resolve names against this).
pub const PROFILES: [PredictorFaultProfile; 6] = [
    NONE,
    PredictorFaultProfile {
        name: "bias-drift",
        drift_every: 120.0,
        drift_len: 60.0,
        drift_lo: 0.65,
        drift_hi: 0.9,
        ..NONE
    },
    PredictorFaultProfile { name: "heavy-tail", tail_prob: 0.08, tail_factor: 4.0, ..NONE },
    PredictorFaultProfile {
        name: "regime-shift",
        shift_every: 60.0,
        shift_len: 30.0,
        shift_factor: 0.6,
        ..NONE
    },
    PredictorFaultProfile { name: "outage", outage_every: 150.0, outage_len: 45.0, ..NONE },
    PredictorFaultProfile {
        name: "full-chaos",
        drift_every: 240.0,
        drift_len: 60.0,
        drift_lo: 0.7,
        drift_hi: 0.9,
        tail_prob: 0.04,
        tail_factor: 3.0,
        shift_every: 180.0,
        shift_len: 40.0,
        shift_factor: 0.7,
        outage_every: 300.0,
        outage_len: 30.0,
        ..NONE
    },
];

/// Resolve a profile by registry name.
pub fn by_name(name: &str) -> Option<PredictorFaultProfile> {
    PROFILES.iter().find(|p| p.name == name).copied()
}

/// All registry names, `"none"` first.
pub fn all_profiles() -> Vec<&'static str> {
    PROFILES.iter().map(|p| p.name).collect()
}

/// The episode kind an event belongs to (outages have no factor — the
/// fallback estimate takes over entirely).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    Drift,
    Shift,
    Outage,
}

impl FaultKind {
    fn rank(self) -> u8 {
        match self {
            FaultKind::Drift => 0,
            FaultKind::Shift => 1,
            FaultKind::Outage => 2,
        }
    }
}

/// One scheduled fault episode: active over `[at, at + len)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    pub at: f64,
    pub len: f64,
    pub kind: FaultKind,
    /// Multiplicative factor applied to predictions during the episode
    /// (1.0 and unused for outages).
    pub factor: f64,
}

/// A jittered-periodic episode process (mirrors `fleet::faults`'s event
/// processes): episode `k` starts uniformly inside the middle half of
/// period `k`, and its factor is drawn eagerly with the start time so
/// the stream position is a pure function of the episode index.
#[derive(Debug, Clone)]
struct Episodes {
    kind: FaultKind,
    every: f64,
    len: f64,
    lo: f64,
    hi: f64,
    k: u64,
    rng: Rng,
    /// Most recently started episode (may have ended already).
    cur: Option<FaultEvent>,
    /// Next scheduled episode.
    next: Option<FaultEvent>,
}

impl Episodes {
    fn new(kind: FaultKind, every: f64, len: f64, lo: f64, hi: f64, seed: u64) -> Self {
        let mut ep =
            Episodes { kind, every, len, lo, hi, k: 0, rng: Rng::new(seed), cur: None, next: None };
        if every > 0.0 {
            ep.next = Some(ep.draw());
        }
        ep
    }

    fn draw(&mut self) -> FaultEvent {
        let at = (self.k as f64 + 0.25 + 0.5 * self.rng.f64()) * self.every;
        let factor = self.lo + self.rng.f64() * (self.hi - self.lo);
        self.k += 1;
        FaultEvent { at, len: self.len, kind: self.kind, factor }
    }

    /// Move the cursor forward: every episode whose start has passed
    /// becomes the current one. Time must be fed monotonically.
    fn advance_to(&mut self, t: f64) {
        while let Some(ev) = self.next {
            if ev.at > t {
                break;
            }
            self.cur = Some(ev);
            self.next = Some(self.draw());
        }
    }

    /// The episode active at `t`, if any.
    fn active(&self, t: f64) -> Option<FaultEvent> {
        self.cur.filter(|ev| t < ev.at + ev.len)
    }
}

/// Sub-stream indices off the wrapper seed (mirrors
/// `fleet::faults::Injector`): each fault process owns an independent
/// stream, so profiles sharing a process kind share its episode
/// timeline at the same seed.
const SUB_DRIFT: u64 = 0;
const SUB_SHIFT: u64 = 1;
const SUB_OUTAGE: u64 = 2;
const SUB_TAIL: u64 = 3;

fn episodes_for(profile: &PredictorFaultProfile, seed: u64) -> (Episodes, Episodes, Episodes) {
    (
        Episodes::new(
            FaultKind::Drift,
            profile.drift_every,
            profile.drift_len,
            profile.drift_lo,
            profile.drift_hi,
            derive_seed(seed, SUB_DRIFT),
        ),
        Episodes::new(
            FaultKind::Shift,
            profile.shift_every,
            profile.shift_len,
            profile.shift_factor,
            profile.shift_factor,
            derive_seed(seed, SUB_SHIFT),
        ),
        Episodes::new(
            FaultKind::Outage,
            profile.outage_every,
            profile.outage_len,
            1.0,
            1.0,
            derive_seed(seed, SUB_OUTAGE),
        ),
    )
}

/// The full episode timeline of `(profile, seed)` up to `horizon`,
/// ordered by start time (ties broken drift < shift < outage). A pure
/// function — calling it neither requires nor perturbs a wrapper, which
/// is what makes "bit-identical at any thread count" testable directly.
pub fn timeline(profile: &PredictorFaultProfile, seed: u64, horizon: f64) -> Vec<FaultEvent> {
    let (drift, shift, outage) = episodes_for(profile, seed);
    let mut events = Vec::new();
    for mut ep in [drift, shift, outage] {
        while let Some(ev) = ep.next {
            if ev.at >= horizon {
                break;
            }
            events.push(ev);
            ep.cur = Some(ev);
            ep.next = Some(ep.draw());
        }
    }
    events.sort_by(|a, b| {
        a.at.partial_cmp(&b.at).unwrap().then(a.kind.rank().cmp(&b.kind.rank()))
    });
    events
}

/// Composable fault wrapper over any inner predictor. Construct only
/// for active profiles (the harness skips the wrapper for `none`, so
/// fault-free runs stay bit-identical to pre-chaos builds).
///
/// The wrapper tracks its own `(n_pred, n_close)` accuracy against the
/// quantized truth — measuring the *faulted* estimates, which is the
/// degradation `econoserve_predictions_total{verdict}` should surface —
/// and keeps the inner predictor's RNG stream untouched during outages
/// (the server being down consumes no model randomness).
pub struct FaultyPredictor {
    inner: Box<dyn Predictor>,
    profile: PredictorFaultProfile,
    drift: Episodes,
    shift: Episodes,
    outage: Episodes,
    tail_rng: Rng,
    quantum: u32,
    /// Monotone simulated-time cursor (re-routed arrivals may be
    /// observed "in the past"; episodes never rewind).
    now: f64,
    prompt_len: u32,
    n_pred: u64,
    n_close: u64,
    outage_fallbacks: u64,
}

impl FaultyPredictor {
    pub fn new(
        inner: Box<dyn Predictor>,
        profile: PredictorFaultProfile,
        seed: u64,
        quantum: u32,
    ) -> Self {
        let (drift, shift, outage) = episodes_for(&profile, seed);
        FaultyPredictor {
            inner,
            profile,
            drift,
            shift,
            outage,
            tail_rng: Rng::new(derive_seed(seed, SUB_TAIL)),
            quantum: quantum.max(1),
            now: 0.0,
            prompt_len: 1,
            n_pred: 0,
            n_close: 0,
            outage_fallbacks: 0,
        }
    }

    /// Predictions served by the outage fallback instead of the model.
    pub fn outage_fallbacks(&self) -> u64 {
        self.outage_fallbacks
    }

    fn quantize(&self, x: f64) -> u32 {
        let q = self.quantum as f64;
        ((x / q).ceil() * q).max(q) as u32
    }
}

impl Predictor for FaultyPredictor {
    fn observe_request(&mut self, now: f64, prompt_len: u32) {
        self.now = self.now.max(now);
        self.prompt_len = prompt_len.max(1);
        let t = self.now;
        self.drift.advance_to(t);
        self.shift.advance_to(t);
        self.outage.advance_to(t);
        self.inner.observe_request(now, prompt_len);
    }

    fn predict_raw(&mut self, id: ReqId, true_rl: u32) -> u32 {
        let pred = if self.outage.active(self.now).is_some() {
            // Predictor unreachable: conservative prompt-proportional
            // fallback. The inner predictor is not consulted, so its
            // error stream does not advance.
            self.outage_fallbacks += 1;
            self.quantize(self.prompt_len as f64 * self.profile.fallback_scale)
        } else {
            let mut p = self.inner.predict_raw(id, true_rl) as f64;
            if let Some(ev) = self.drift.active(self.now) {
                p *= ev.factor;
            }
            if let Some(ev) = self.shift.active(self.now) {
                p *= ev.factor;
            }
            if self.profile.tail_prob > 0.0 && self.tail_rng.chance(self.profile.tail_prob) {
                p = if self.tail_rng.chance(0.5) {
                    p / self.profile.tail_factor
                } else {
                    p * self.profile.tail_factor
                };
            }
            self.quantize(p)
        };
        self.n_pred += 1;
        if pred.abs_diff(self.quantize(true_rl as f64)) <= self.quantum {
            self.n_close += 1;
        }
        pred
    }

    fn latency(&self) -> f64 {
        self.inner.latency()
    }

    fn accuracy(&self) -> (u64, u64) {
        (self.n_pred, self.n_close)
    }

    fn name(&self) -> &'static str {
        "faulted"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::predictor::OraclePredictor;

    #[test]
    fn registry_resolves_every_profile() {
        assert_eq!(all_profiles().len(), PROFILES.len());
        assert_eq!(all_profiles()[0], "none");
        for name in all_profiles() {
            let p = by_name(name).unwrap();
            assert_eq!(p.name, name);
            assert_eq!(p.is_active(), name != "none");
        }
        assert!(by_name("meteor-strike").is_none());
    }

    #[test]
    fn none_profile_has_empty_timeline() {
        assert!(timeline(&by_name("none").unwrap(), 42, 1e6).is_empty());
    }

    #[test]
    fn timelines_are_seed_deterministic() {
        for name in all_profiles() {
            let p = by_name(name).unwrap();
            let a = timeline(&p, 7, 2000.0);
            let b = timeline(&p, 7, 2000.0);
            assert_eq!(a, b, "{name}: same (profile, seed) must give the same timeline");
            if p.is_active() {
                let c = timeline(&p, 8, 2000.0);
                assert_ne!(a, c, "{name}: different seeds must differ");
            }
        }
    }

    #[test]
    fn events_are_ordered_and_inside_their_jitter_windows() {
        let p = by_name("regime-shift").unwrap();
        let evs = timeline(&p, 42, 10.0 * p.shift_every);
        assert!(evs.len() >= 8, "expected ~10 episodes, got {}", evs.len());
        for (k, ev) in evs.iter().enumerate() {
            assert_eq!(ev.kind, FaultKind::Shift);
            assert_eq!(ev.len, p.shift_len);
            let lo = (k as f64 + 0.25) * p.shift_every;
            let hi = (k as f64 + 0.75) * p.shift_every;
            assert!(
                ev.at >= lo && ev.at < hi,
                "episode {k} at {} outside jitter window [{lo}, {hi})",
                ev.at
            );
        }
    }

    #[test]
    fn full_chaos_interleaves_kinds_in_order() {
        let evs = timeline(&by_name("full-chaos").unwrap(), 13, 3000.0);
        let kinds: std::collections::HashSet<u8> = evs.iter().map(|e| e.kind.rank()).collect();
        assert_eq!(kinds.len(), 3, "all three episode kinds must appear");
        for w in evs.windows(2) {
            assert!(w[0].at <= w[1].at, "timeline must be ordered by start time");
        }
    }

    #[test]
    fn drift_scales_predictions_down_during_episodes() {
        let p = by_name("bias-drift").unwrap();
        let ev = timeline(&p, 5, 1000.0)[0];
        let mut f = FaultyPredictor::new(Box::new(OraclePredictor::new(1)), p, 5, 1);
        // Before the episode: passthrough.
        f.observe_request(ev.at - 1.0, 100);
        assert_eq!(f.predict_raw(0, 1000), 1000);
        // Inside: scaled by the episode factor (within the profile band).
        f.observe_request(ev.at + 0.5 * ev.len, 100);
        let scaled = f.predict_raw(1, 1000);
        assert_eq!(scaled, (1000.0 * ev.factor).ceil() as u32);
        assert!(ev.factor >= p.drift_lo && ev.factor <= p.drift_hi);
        // After: passthrough again.
        f.observe_request(ev.at + ev.len + 0.1, 100);
        assert_eq!(f.predict_raw(2, 1000), 1000);
        let (n, close) = f.accuracy();
        assert_eq!(n, 3);
        assert_eq!(close, 2, "only the in-episode prediction is off");
    }

    #[test]
    fn outage_falls_back_to_prompt_proportional_estimate() {
        let p = by_name("outage").unwrap();
        let ev = timeline(&p, 11, 2000.0)[0];
        assert_eq!(ev.kind, FaultKind::Outage);
        let mut f = FaultyPredictor::new(Box::new(OraclePredictor::new(32)), p, 11, 32);
        f.observe_request(ev.at + 1.0, 200);
        let pred = f.predict_raw(0, 64);
        let want = ((200.0 * p.fallback_scale) / 32.0).ceil() as u32 * 32;
        assert_eq!(pred, want, "fallback must be prompt-proportional and quantized");
        assert_eq!(f.outage_fallbacks(), 1);
        // Past the window the oracle answers again.
        f.observe_request(ev.at + ev.len + 1.0, 200);
        assert_eq!(f.predict_raw(1, 64), 64);
        assert_eq!(f.outage_fallbacks(), 1);
    }

    #[test]
    fn heavy_tail_blunders_at_roughly_profile_probability() {
        let p = by_name("heavy-tail").unwrap();
        let mut f = FaultyPredictor::new(Box::new(OraclePredictor::new(1)), p, 3, 1);
        f.observe_request(0.0, 50);
        let n = 20_000;
        let mut blunders = 0;
        for i in 0..n {
            let pred = f.predict_raw(i, 400);
            if pred != 400 {
                blunders += 1;
                assert!(
                    pred == 100 || pred == 1600,
                    "tail blunder must be x{} or /{}: {pred}",
                    p.tail_factor,
                    p.tail_factor
                );
            }
        }
        let frac = blunders as f64 / n as f64;
        assert!(
            (frac - p.tail_prob).abs() < 0.02,
            "blunder rate {frac} vs tail_prob {}",
            p.tail_prob
        );
    }
}
