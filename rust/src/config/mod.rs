//! Configuration: hardware/model cost profiles and the system-level knobs.
//!
//! Profiles translate the paper's testbed (A100-80GB, OPT-13B/33B/175B,
//! NVSwitch/Ethernet) into an analytic cost model the simulation engine
//! uses. Absolute numbers are derived from public A100 specs and common
//! MFU assumptions; the figures only depend on *relative* costs (who wins,
//! where crossovers fall), which these preserve. See DESIGN.md
//! §Substitutions.

use crate::core::Time;

/// Hardware + model cost profile for the analytic engine.
#[derive(Debug, Clone)]
pub struct ModelProfile {
    pub name: &'static str,
    /// Parameters in billions.
    pub params_b: f64,
    pub n_layers: u32,
    pub hidden: u32,
    /// Context limit (prompt + response) in tokens.
    pub max_total_len: u32,
    /// KVC capacity in bytes (the paper: 12 GB for OPT-13B on one A100,
    /// 19.2 GB for Llama-33B over 2 GPUs, 264 GB for OPT-175B over 8).
    pub kvc_bytes: u64,
    /// Effective peak compute (FLOP/s) across the GPUs serving one replica,
    /// already derated to a realistic MFU.
    pub peak_flops: f64,
    /// Effective HBM bandwidth (bytes/s) across those GPUs.
    pub mem_bw: f64,
    /// Weight bytes streamed per iteration (fp16).
    pub weight_bytes: f64,
    /// Per-iteration fixed overhead (kernel launches, sampling, host sync).
    pub iter_overhead: Time,
    /// Target forward size: tokens per iteration that saturate GPU compute
    /// (set per FastGen's method: the knee of the throughput curve).
    pub tfs: u32,
    /// GPUs occupied by one replica of this model.
    pub gpus_per_replica: u32,
}

impl ModelProfile {
    /// fp16 KV bytes per token: 2 (K and V) * layers * hidden * 2 bytes.
    pub fn kv_bytes_per_token(&self) -> u64 {
        2 * self.n_layers as u64 * self.hidden as u64 * 2
    }

    /// Total KVC capacity in tokens.
    pub fn kvc_tokens(&self) -> u32 {
        (self.kvc_bytes / self.kv_bytes_per_token()) as u32
    }

    /// Dense FLOPs to process one token through the model (2 * params).
    pub fn flops_per_token(&self) -> f64 {
        2.0 * self.params_b * 1e9
    }

    pub fn opt_13b() -> Self {
        ModelProfile {
            name: "opt-13b",
            params_b: 13.0,
            n_layers: 40,
            hidden: 5120,
            max_total_len: 4096,
            kvc_bytes: 12 * (1 << 30),
            // One A100: 312 TFLOPS bf16 peak, ~50% MFU sustained.
            peak_flops: 156e12,
            // 2.0 TB/s HBM2e, ~65% achievable.
            mem_bw: 1.3e12,
            weight_bytes: 26e9,
            iter_overhead: 1.5e-3,
            tfs: 2048,
            gpus_per_replica: 1,
        }
    }

    pub fn llama_33b() -> Self {
        ModelProfile {
            name: "llama-33b",
            params_b: 33.0,
            n_layers: 60,
            hidden: 6656,
            max_total_len: 4096,
            kvc_bytes: (19.2 * (1u64 << 30) as f64) as u64,
            // Two A100s, tensor-parallel: ~45% MFU after comm overhead.
            peak_flops: 280e12,
            mem_bw: 2.6e12,
            weight_bytes: 66e9,
            iter_overhead: 2.0e-3,
            tfs: 3072,
            gpus_per_replica: 2,
        }
    }

    pub fn opt_175b() -> Self {
        ModelProfile {
            name: "opt-175b",
            params_b: 175.0,
            n_layers: 96,
            hidden: 12288,
            max_total_len: 4096,
            kvc_bytes: 264 * (1 << 30),
            // Eight A100s, tensor-parallel: ~40% MFU.
            peak_flops: 1.0e15,
            mem_bw: 10.4e12,
            weight_bytes: 350e9,
            iter_overhead: 3.5e-3,
            tfs: 4096,
            gpus_per_replica: 8,
        }
    }

    /// H100 variant of a profile (for Fig 12's heterogeneous setting):
    /// ~2.5x compute, ~1.6x bandwidth vs A100.
    pub fn h100_scaled(&self) -> Self {
        let mut p = self.clone();
        p.peak_flops *= 2.5;
        p.mem_bw *= 1.65;
        p
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "opt-13b" => Some(Self::opt_13b()),
            "llama-33b" => Some(Self::llama_33b()),
            "opt-175b" => Some(Self::opt_175b()),
            // Small profile for the large-scale Fig 12c simulation.
            "llama3-8b" => Some(ModelProfile {
                name: "llama3-8b",
                params_b: 8.0,
                n_layers: 32,
                hidden: 4096,
                max_total_len: 4096,
                kvc_bytes: 40 * (1 << 30),
                peak_flops: 170e12,
                mem_bw: 1.4e12,
                weight_bytes: 16e9,
                iter_overhead: 1.0e-3,
                tfs: 2048,
                gpus_per_replica: 1,
            }),
            _ => None,
        }
    }
}

/// Preemption recovery modes on KVC allocation failure (§2.3, Fig 5b).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PreemptMode {
    /// vLLM-style: swap KV blocks to CPU over PCIe, swap back on resume.
    OffloadSwap,
    /// Drop the KV data, keep bookkeeping; recompute prefix on resume
    /// (costed as a prefill of the existing context).
    OffloadFree,
    /// First try the PT-reserved KVC, fall back to OffloadFree.
    ReservedThenFree,
}

/// System-level knobs shared by every scheduler.
#[derive(Debug, Clone)]
pub struct SystemConfig {
    pub profile: ModelProfile,
    /// KVC block size in tokens (vLLM default 32; the paper uses 32).
    pub block_size: u32,
    /// JCT SLO scale (paper default 2.0).
    pub slo_scale: f64,
    /// Padding ratio added to RL predictions (paper sweet spots: 0.10
    /// Alpaca / 0.15 ShareGPT / 0.20 BookCorpus).
    pub padding_ratio: f64,
    /// Fraction of KVC reserved for PTs (paper: 0.012/0.03/0.05 in §2,
    /// tuned to 0.02/0.03/0.04 in Fig 15c).
    pub reserve_frac: f64,
    /// KVCPipe buffer b, as a fraction of the hosting RL (paper: 0.15/
    /// 0.15/0.10).
    pub buffer_frac: f64,
    /// Preemption mode for RL under-provision.
    pub preempt_mode: PreemptMode,
    /// Multiplier applied to *measured* rust scheduling wall-time when
    /// charging it to the simulation clock. The paper's baselines are
    /// Python (vLLM) — rust is ~50x faster at the same algorithmic cost —
    /// so the default recreates the paper's overhead regime. Set to 1.0
    /// to charge native rust cost (reported separately in Fig 14).
    pub sched_time_scale: f64,
    /// PCIe bandwidth for KV offload (bytes/s) — swap cost model.
    pub pcie_bw: f64,
    /// Mean prompt-processing and per-token generation latency used in the
    /// SLO formula (filled in by calibration; see `slo::calibrate`).
    pub t_p: Time,
    pub t_g: Time,
    /// Cap on idle waiting-GT prompt KV, as a fraction of KVC capacity:
    /// the GT "staging pool" that feeds time-synced grouping and KVC
    /// pipelining. Beyond it, new PT prefills pause (backlog stays in the
    /// KVC-free PT queue).
    pub gt_stage_frac: f64,
    /// Multiplicative bias applied by `SimPredictor` (1.0 = calibrated;
    /// `< 1` systematically under-predicts). CLI: `--predictor-bias`.
    pub predictor_bias: f64,
    /// Predictor fault-injection profile (`predictor::faults::by_name`
    /// registry; `"none"` = no wrapper, bit-identical to pre-chaos
    /// builds). CLI: `--predictor-faults`.
    pub predictor_faults: String,
    /// KVC headroom mode (`reliability::headroom::HeadroomConfig::parse`
    /// grammar): `"static"` keeps `padding_ratio` fixed; `"adaptive"`
    /// steers it online toward a target under-provision rate and bounds
    /// overrun evictions per iteration. CLI: `--headroom`.
    pub headroom: String,
    /// Seed for all stochastic components.
    pub seed: u64,
}

impl SystemConfig {
    pub fn new(profile: ModelProfile) -> Self {
        SystemConfig {
            profile,
            block_size: 32,
            slo_scale: 2.0,
            padding_ratio: 0.15,
            reserve_frac: 0.03,
            buffer_frac: 0.15,
            preempt_mode: PreemptMode::ReservedThenFree,
            sched_time_scale: 50.0,
            pcie_bw: 24e9, // PCIe 4.0 x16 effective
            t_p: 0.05,
            t_g: 0.02,
            gt_stage_frac: 0.05,
            predictor_bias: 1.0,
            predictor_faults: "none".to_string(),
            headroom: "static".to_string(),
            seed: 42,
        }
    }

    pub fn kvc_tokens(&self) -> u32 {
        self.profile.kvc_tokens()
    }

    pub fn reserve_tokens(&self) -> u32 {
        (self.kvc_tokens() as f64 * self.reserve_frac) as u32
    }

    /// Apply padding to a raw RL prediction (at least one token).
    pub fn pad_prediction(&self, raw: u32) -> u32 {
        Self::pad_with(raw, self.padding_ratio)
    }

    /// Padding with an explicit ratio — the adaptive headroom controller
    /// (`reliability::headroom`) substitutes its steered ratio for the
    /// static `padding_ratio` through this.
    pub fn pad_with(raw: u32, ratio: f64) -> u32 {
        ((raw as f64 * (1.0 + ratio)).ceil() as u32).max(1)
    }

    /// The JCT SLO for a request with true RL `rl` (absolute deadline is
    /// arrival + this).
    pub fn slo_budget(&self, rl: u32) -> Time {
        self.slo_scale * (self.t_p + self.t_g * rl as f64)
    }

    /// Crude single-replica capacity estimate (req/s) for a trace's
    /// length mix: min of the compute and KVC rooflines. Used to scale
    /// experiment rate grids (`figures::common`) and as the forecast
    /// autoscaler's per-replica serving-rate prior (`fleet`).
    pub fn capacity_estimate(&self, spec: &crate::trace::TraceSpec) -> f64 {
        let total_tokens = spec.input.avg + spec.output.avg;
        let compute_cap =
            self.profile.peak_flops / (self.profile.flops_per_token() * total_tokens);
        // KVC: avg resident footprint ~ prompt + RL/2; service ~ RL * t_g.
        let footprint = spec.input.avg + spec.output.avg / 2.0;
        let service = spec.output.avg * self.t_g;
        let kvc_cap = self.profile.kvc_tokens() as f64 / footprint / service;
        compute_cap.min(kvc_cap)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kv_bytes_match_paper_scale() {
        let p = ModelProfile::opt_13b();
        // 2 * 40 * 5120 * 2B = 819,200 B/token
        assert_eq!(p.kv_bytes_per_token(), 819_200);
        // 12 GB / 0.82 MB ~ 15.7k tokens
        let tokens = p.kvc_tokens();
        assert!((15_000..16_500).contains(&tokens), "tokens={tokens}");
    }

    #[test]
    fn profiles_resolve_by_name() {
        for name in ["opt-13b", "llama-33b", "opt-175b", "llama3-8b"] {
            assert!(ModelProfile::by_name(name).is_some(), "{name}");
        }
        assert!(ModelProfile::by_name("gpt-5").is_none());
    }

    #[test]
    fn padding_is_monotone_and_min_one() {
        let cfg = SystemConfig::new(ModelProfile::opt_13b());
        assert_eq!(cfg.pad_prediction(0), 1);
        assert!(cfg.pad_prediction(100) >= 100);
        assert!(cfg.pad_prediction(200) >= cfg.pad_prediction(100));
    }

    #[test]
    fn slo_budget_scales_with_rl() {
        let mut cfg = SystemConfig::new(ModelProfile::opt_13b());
        cfg.t_p = 0.1;
        cfg.t_g = 0.01;
        cfg.slo_scale = 2.0;
        let b = cfg.slo_budget(100);
        assert!((b - 2.0 * (0.1 + 1.0)).abs() < 1e-12);
    }

    #[test]
    fn h100_is_faster() {
        let a = ModelProfile::opt_13b();
        let h = a.h100_scaled();
        assert!(h.peak_flops > a.peak_flops);
        assert!(h.mem_bw > a.mem_bw);
    }
}
