//! Metrics: per-iteration utilization sampling, counters, and the summary
//! statistics every paper figure is built from.

use crate::core::{ReqRec, Time};
use crate::util::stats::Samples;

/// Time-bucketed utilization sampling (the paper samples gpustat at 1 s).
#[derive(Debug, Clone)]
pub struct UtilSampler {
    bucket: f64,
    /// (sum of dur-weighted value, sum of dur) per bucket.
    acc: Vec<(f64, f64)>,
}

impl UtilSampler {
    pub fn new(bucket: f64) -> Self {
        UtilSampler { bucket, acc: Vec::new() }
    }

    pub fn add(&mut self, t: Time, dur: f64, value: f64) {
        // Guard degenerate inputs: a NaN/∞ or negative timestamp would
        // cast to 0 or usize::MAX below (the latter a catastrophic
        // resize), and a non-positive duration carries no weight — the
        // bucket would exist but be excluded from `series()` anyway.
        if !t.is_finite() || t < 0.0 || !dur.is_finite() || dur <= 0.0 {
            return;
        }
        let idx = (t / self.bucket) as usize;
        if idx >= self.acc.len() {
            // `resize` zero-fills every intermediate bucket, so a sparse
            // time jump leaves explicit (0.0, 0.0) gaps that `mean()`
            // and `series()` skip by weight.
            self.acc.resize(idx + 1, (0.0, 0.0));
        }
        self.acc[idx].0 += value * dur;
        self.acc[idx].1 += dur;
    }

    /// Time-weighted mean across all buckets.
    pub fn mean(&self) -> f64 {
        let (num, den) = self
            .acc
            .iter()
            .fold((0.0, 0.0), |(n, d), (bn, bd)| (n + bn, d + bd));
        if den > 0.0 {
            num / den
        } else {
            0.0
        }
    }

    /// Per-bucket series (bucket start time, mean value).
    pub fn series(&self) -> Vec<(f64, f64)> {
        self.acc
            .iter()
            .enumerate()
            .filter(|(_, (_, d))| *d > 0.0)
            .map(|(i, (n, d))| (i as f64 * self.bucket, n / d))
            .collect()
    }
}

/// Collector the engine/coordinator feeds during a run.
#[derive(Debug, Clone)]
pub struct Collector {
    pub kvc_util: UtilSampler,
    pub kvc_alloc: UtilSampler,
    pub gpu_util: UtilSampler,
    pub forward_size: UtilSampler,
    /// Histogram of completed-requests-per-iteration (Fig 1f); index =
    /// completions, value = iteration count.
    pub completions_per_iter: Vec<u64>,
    pub iterations: u64,
    pub sched_time_total: f64,
    pub sched_time_samples: Samples,
    pub preemptions: u64,
    pub swap_preemptions: u64,
    pub pipeline_evictions: u64,
    /// Largest number of overrun-guest evictions executed in any single
    /// iteration (the eviction-storm containment bound: with adaptive
    /// headroom this never exceeds the configured per-iteration budget).
    pub max_iter_evictions: u64,
    /// Iterations whose overrun sweep hit the eviction budget and had to
    /// defer at least one eviction to the next iteration.
    pub eviction_storms: u64,
    /// Cumulative typed allocation outcomes, folded in per iteration by
    /// `World::apply_plan` from the allocator's `AllocTally`.
    pub alloc_granted: u64,
    pub alloc_hosted: u64,
    pub alloc_exhausted: u64,
    /// Requests that suffered >= 1 KVC allocation failure.
    pub alloc_failed_reqs: std::collections::HashSet<usize>,
    /// Total busy (iteration) time, for GPU-time accounting.
    pub busy_time: f64,
    /// Allocation breakdown samplers (sampled sparsely): tokens allocated
    /// to RUNNING requests that are written / unwritten, and tokens held
    /// by WAITING (queued/preempted) requests.
    pub brk_running_written: UtilSampler,
    pub brk_running_unwritten: UtilSampler,
    pub brk_waiting_held: UtilSampler,
    /// Occupied-KVC samples of QUEUED tasks by category (Fig 6): fresh
    /// GTs (never preempted), preempted GTs, and chunked prompts.
    pub occ_new_gt: Samples,
    pub occ_preempted_gt: Samples,
    pub occ_chunked_pt: Samples,
}

impl Default for Collector {
    fn default() -> Self {
        Self::new()
    }
}

impl Collector {
    pub fn new() -> Self {
        Collector {
            kvc_util: UtilSampler::new(1.0),
            kvc_alloc: UtilSampler::new(1.0),
            gpu_util: UtilSampler::new(1.0),
            forward_size: UtilSampler::new(1.0),
            completions_per_iter: Vec::new(),
            iterations: 0,
            sched_time_total: 0.0,
            sched_time_samples: Samples::new(),
            preemptions: 0,
            swap_preemptions: 0,
            pipeline_evictions: 0,
            max_iter_evictions: 0,
            eviction_storms: 0,
            alloc_granted: 0,
            alloc_hosted: 0,
            alloc_exhausted: 0,
            alloc_failed_reqs: std::collections::HashSet::new(),
            busy_time: 0.0,
            brk_running_written: UtilSampler::new(1.0),
            brk_running_unwritten: UtilSampler::new(1.0),
            brk_waiting_held: UtilSampler::new(1.0),
            occ_new_gt: Samples::new(),
            occ_preempted_gt: Samples::new(),
            occ_chunked_pt: Samples::new(),
        }
    }

    pub fn record_iteration(
        &mut self,
        t: Time,
        dur: f64,
        forward: u32,
        gpu_util: f64,
        kvc_util: f64,
        kvc_alloc: f64,
        completed: usize,
    ) {
        self.iterations += 1;
        self.busy_time += dur;
        self.forward_size.add(t, dur, forward as f64);
        self.gpu_util.add(t, dur, gpu_util);
        self.kvc_util.add(t, dur, kvc_util);
        self.kvc_alloc.add(t, dur, kvc_alloc);
        if completed >= self.completions_per_iter.len() {
            self.completions_per_iter.resize(completed + 1, 0);
        }
        self.completions_per_iter[completed] += 1;
    }

    pub fn record_sched(&mut self, dur: f64) {
        self.sched_time_total += dur;
        self.sched_time_samples.push(dur);
    }

    /// Fold one iteration's typed allocation outcomes into the counters.
    pub fn record_alloc_tally(&mut self, tally: crate::kvc::AllocTally) {
        self.alloc_granted += tally.granted as u64;
        self.alloc_hosted += tally.hosted as u64;
        self.alloc_exhausted += tally.exhausted as u64;
    }
}

/// End-of-run summary over completed requests (the figure drivers' input).
#[derive(Debug, Clone, Default)]
pub struct Summary {
    pub n_total: usize,
    pub n_done: usize,
    /// Requests completed per second of simulated wall time.
    pub throughput_rps: f64,
    /// Generated tokens per second.
    pub throughput_tps: f64,
    pub mean_jct: f64,
    pub p5_jct: f64,
    pub p95_jct: f64,
    /// Mean of per-request JCT / output length (vLLM's normalized latency).
    pub norm_latency: f64,
    /// SLO satisfaction ratio over ALL requests (unfinished = violated).
    pub ssr: f64,
    pub mean_tbt: f64,
    pub p5_tbt: f64,
    pub p95_tbt: f64,
    /// JCT decomposition (means over completed requests).
    pub mean_wait: f64,
    pub mean_exec: f64,
    pub mean_preempt: f64,
    pub mean_sched_share: f64,
    /// Time-weighted utilizations.
    pub kvc_util: f64,
    pub kvc_alloc: f64,
    pub gpu_util: f64,
    pub avg_forward_size: f64,
    /// Fraction of requests that hit >= 1 KVC allocation failure.
    pub alloc_failure_frac: f64,
    pub preemptions: u64,
    pub pipeline_evictions: u64,
    /// Worst single-iteration overrun-eviction count (storm bound).
    pub max_iter_evictions: u64,
    /// Iterations that saturated the per-iteration eviction budget.
    pub eviction_storms: u64,
    /// RL predictions issued / "close" verdicts (within one quantum of
    /// the quantized truth). Filled by callers that own the predictor
    /// (`summarize` itself never sees it); zeros otherwise.
    pub n_pred: u64,
    pub n_close: u64,
    /// Scheduling overhead as a fraction of total busy time.
    pub sched_overhead_frac: f64,
    pub sched_time_mean: f64,
    pub iterations: u64,
}

/// Build the summary from request records + collector at `end_time`.
pub fn summarize(recs: &[ReqRec], col: &Collector, end_time: Time) -> Summary {
    let mut jct = Samples::new();
    let mut tbt = Samples::new();
    let mut norm = Samples::new();
    let mut wait = Samples::new();
    let mut exec = Samples::new();
    let mut preempt = Samples::new();
    let mut tokens = 0u64;
    let mut n_done = 0usize;
    let mut slo_ok = 0usize;

    for r in recs {
        if let Some(j) = r.jct() {
            n_done += 1;
            jct.push(j);
            norm.push(j / r.req.true_rl.max(1) as f64);
            if r.met_slo() {
                slo_ok += 1;
            }
            tokens += r.generated as u64;
            if let Some(t) = r.mean_tbt() {
                tbt.push(t);
            }
            let w = r.exec_start_at.map(|s| s - r.req.arrival).unwrap_or(0.0);
            wait.push(w);
            preempt.push(r.preempt_total);
            exec.push((j - w - r.preempt_total).max(0.0));
        }
    }

    let span = end_time.max(1e-9);
    let mut s = Summary {
        n_total: recs.len(),
        n_done,
        throughput_rps: n_done as f64 / span,
        throughput_tps: tokens as f64 / span,
        mean_jct: jct.mean(),
        p5_jct: jct.p5(),
        p95_jct: jct.p95(),
        norm_latency: norm.mean(),
        ssr: slo_ok as f64 / recs.len().max(1) as f64,
        mean_tbt: tbt.mean(),
        p5_tbt: tbt.p5(),
        p95_tbt: tbt.p95(),
        mean_wait: wait.mean(),
        mean_exec: exec.mean(),
        mean_preempt: preempt.mean(),
        mean_sched_share: if n_done > 0 { col.sched_time_total / n_done as f64 } else { 0.0 },
        kvc_util: col.kvc_util.mean(),
        kvc_alloc: col.kvc_alloc.mean(),
        gpu_util: col.gpu_util.mean(),
        avg_forward_size: col.forward_size.mean(),
        alloc_failure_frac: col.alloc_failed_reqs.len() as f64 / recs.len().max(1) as f64,
        preemptions: col.preemptions,
        pipeline_evictions: col.pipeline_evictions,
        max_iter_evictions: col.max_iter_evictions,
        eviction_storms: col.eviction_storms,
        n_pred: 0,
        n_close: 0,
        sched_overhead_frac: col.sched_time_total / (col.busy_time + col.sched_time_total).max(1e-9),
        sched_time_mean: 0.0,
        iterations: col.iterations,
    };
    let mut sched = col.sched_time_samples.clone();
    s.sched_time_mean = sched.mean();
    let _ = sched.p95();
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{Request, ReqRec};

    fn done_rec(id: usize, arrival: f64, done: f64, rl: u32, deadline: f64) -> ReqRec {
        let mut r = ReqRec::new(Request { id, arrival, prompt_len: 10, true_rl: rl, deadline });
        r.generated = rl;
        r.done_at = Some(done);
        r.exec_start_at = Some(arrival + 0.5);
        r.phase = crate::core::Phase::Done;
        r
    }

    #[test]
    fn util_sampler_time_weighted() {
        let mut u = UtilSampler::new(1.0);
        u.add(0.0, 1.0, 1.0);
        u.add(0.5, 3.0, 0.0);
        assert!((u.mean() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn util_sampler_empty_mean_is_zero() {
        let u = UtilSampler::new(1.0);
        assert_eq!(u.mean(), 0.0);
        assert!(u.series().is_empty());
    }

    #[test]
    fn util_sampler_exact_boundary_lands_in_upper_bucket() {
        // t == k * bucket belongs to bucket k (half-open [k, k+1) buckets).
        let mut u = UtilSampler::new(1.0);
        u.add(2.0, 1.0, 0.7);
        let s = u.series();
        assert_eq!(s.len(), 1);
        assert_eq!(s[0].0, 2.0);
        assert!((s[0].1 - 0.7).abs() < 1e-12);
        // And the boundary sample shares its bucket with interior times.
        u.add(2.9, 1.0, 0.3);
        let s = u.series();
        assert_eq!(s.len(), 1);
        assert!((s[0].1 - 0.5).abs() < 1e-12);
    }

    #[test]
    fn util_sampler_sparse_jump_zero_fills_gap_buckets() {
        let mut u = UtilSampler::new(1.0);
        u.add(0.5, 1.0, 1.0);
        u.add(1000.5, 1.0, 1.0);
        // Gap buckets exist (zero-weighted) but are excluded from the
        // series and carry no weight in the mean.
        let s = u.series();
        assert_eq!(s.len(), 2);
        assert_eq!(s[0].0, 0.0);
        assert_eq!(s[1].0, 1000.0);
        assert!((u.mean() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn util_sampler_rejects_degenerate_inputs() {
        let mut u = UtilSampler::new(1.0);
        u.add(f64::NAN, 1.0, 0.5);
        u.add(f64::INFINITY, 1.0, 0.5); // would resize to usize::MAX
        u.add(-3.0, 1.0, 0.5);
        u.add(1.0, 0.0, 0.5);
        u.add(1.0, f64::NAN, 0.5);
        assert!(u.series().is_empty());
        assert_eq!(u.mean(), 0.0);
        // A valid sample afterwards still lands correctly.
        u.add(1.0, 2.0, 0.25);
        assert_eq!(u.series(), vec![(1.0, 0.25)]);
    }

    #[test]
    fn util_series_buckets() {
        let mut u = UtilSampler::new(1.0);
        u.add(0.2, 0.5, 0.8);
        u.add(2.3, 0.5, 0.4);
        let s = u.series();
        assert_eq!(s.len(), 2);
        assert_eq!(s[0].0, 0.0);
        assert_eq!(s[1].0, 2.0);
    }

    #[test]
    fn summary_basics() {
        let recs = vec![
            done_rec(0, 0.0, 2.0, 10, 3.0),  // met SLO
            done_rec(1, 1.0, 9.0, 20, 4.0),  // missed SLO
        ];
        let col = Collector::new();
        let s = summarize(&recs, &col, 10.0);
        assert_eq!(s.n_done, 2);
        assert!((s.ssr - 0.5).abs() < 1e-12);
        assert!((s.mean_jct - 5.0).abs() < 1e-12);
        assert!((s.throughput_rps - 0.2).abs() < 1e-12);
        // norm latency: (2/10 + 8/20)/2 = 0.3
        assert!((s.norm_latency - 0.3).abs() < 1e-12);
    }

    #[test]
    fn unfinished_requests_count_against_ssr() {
        let mut recs = vec![done_rec(0, 0.0, 1.0, 10, 2.0)];
        recs.push(ReqRec::new(Request {
            id: 1,
            arrival: 0.0,
            prompt_len: 5,
            true_rl: 5,
            deadline: 1.0,
        }));
        let s = summarize(&recs, &Collector::new(), 10.0);
        assert_eq!(s.n_done, 1);
        assert!((s.ssr - 0.5).abs() < 1e-12);
    }

    #[test]
    fn completions_histogram() {
        let mut c = Collector::new();
        c.record_iteration(0.0, 0.01, 100, 0.9, 0.5, 0.6, 0);
        c.record_iteration(0.01, 0.01, 100, 0.9, 0.5, 0.6, 3);
        assert_eq!(c.completions_per_iter[0], 1);
        assert_eq!(c.completions_per_iter[3], 1);
    }
}
