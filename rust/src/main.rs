//! EconoServe launcher.
//!
//! Subcommands:
//!   simulate  — run a scheduler over a synthetic trace on the calibrated
//!               engine and print the summary (the paper's single-GPU setup).
//!   serve     — load the AOT artifacts and serve a generated workload on
//!               the REAL model via PJRT (python-free request path;
//!               requires the `pjrt` feature).
//!   sweep     — parallel experiment grid (JSON spec in → one JSON row
//!               per cell out, deterministic at any --threads).
//!   trace     — generate/inspect traces (Table 2 self-check).
//!   capacity  — Fig 12-style min-GPU search vs DistServe.
//!   fleet     — multi-replica fleet: routing + autoscaling + GPU-hour
//!               cost under non-stationary (poisson/mmpp/diurnal) load.
//!   promlint  — strict-parse a Prometheus text file (as written by
//!               `fleet`/`sweep --metrics-out` or scraped from
//!               `GET /metrics`) and verify it re-renders canonically.
//!   tracelint — validate a lifecycle trace (as written by `fleet`/`sweep
//!               --trace-out`): span conservation per request track, and
//!               optionally reconcile span outcomes against a Prometheus
//!               metrics file.
//!   trace-report — per-request time-attribution table from a trace
//!               (queued / prefill / decode / stalled-on-KVC / preempted)
//!               plus the per-scheduler skip-reason breakdown.
//!
//! Run `econoserve <subcommand> --help` for options.

use econoserve::cluster::{DistServeConfig, DistServeSim};
use econoserve::config::{ModelProfile, SystemConfig};
use econoserve::coordinator::{harness, RunLimits};
use econoserve::exp::{self, GridSpec};
use econoserve::fleet::{self, FleetConfig};
use econoserve::telemetry::{trace as tracing, TraceConfig, TraceDoc};
use econoserve::trace::{self, ArrivalProcess, TraceGen, TraceSpec};
use econoserve::util::cli::Cli;
use econoserve::util::json::Json;
use econoserve::util::rng::{derive_seed, stream};

fn main() {
    let mut args = std::env::args().skip(1);
    let cmd = args.next().unwrap_or_else(|| "help".to_string());
    let rest: Vec<String> = args.collect();
    let code = match cmd.as_str() {
        "simulate" => cmd_simulate(rest),
        "serve" => cmd_serve(rest),
        "sweep" => cmd_sweep(rest),
        "trace" => cmd_trace(rest),
        "capacity" => cmd_capacity(rest),
        "fleet" => cmd_fleet(rest),
        "figures" => cmd_figures(rest),
        "promlint" => cmd_promlint(rest),
        "tracelint" => cmd_tracelint(rest),
        "trace-report" => cmd_trace_report(rest),
        _ => {
            eprintln!(
                "usage: econoserve <simulate|serve|sweep|trace|capacity|fleet|figures|promlint|\
                 tracelint|trace-report> [options]\n\
                 try: econoserve simulate --help"
            );
            2
        }
    };
    std::process::exit(code);
}

fn calibrated_cfg(model: &str, trace_name: &str) -> SystemConfig {
    let profile = ModelProfile::by_name(model)
        .unwrap_or_else(|| panic!("unknown model '{model}'"));
    let mut cfg = SystemConfig::new(profile);
    // Trace-specific sweet spots from the paper (§2.3, Fig 15).
    match trace_name {
        "alpaca" => {
            cfg.padding_ratio = 0.10;
            cfg.reserve_frac = 0.02;
            cfg.buffer_frac = 0.15;
        }
        "sharegpt" => {
            cfg.padding_ratio = 0.15;
            cfg.reserve_frac = 0.03;
            cfg.buffer_frac = 0.15;
        }
        "bookcorpus" => {
            cfg.padding_ratio = 0.20;
            cfg.reserve_frac = 0.04;
            cfg.buffer_frac = 0.10;
        }
        _ => {}
    }
    // SLO constants from the cost model (prefill of an average prompt,
    // decode token at typical batch size).
    let spec = TraceSpec::by_name(trace_name).unwrap_or_else(TraceSpec::sharegpt);
    // t_p: prefill of an average prompt (compute-bound estimate);
    // t_g: one decode iteration (weight streaming dominates) — the latency
    // a token experiences regardless of batch co-travellers.
    cfg.t_p = cfg.profile.flops_per_token() * spec.input.avg / cfg.profile.peak_flops
        + cfg.profile.iter_overhead;
    cfg.t_g = cfg.profile.weight_bytes / cfg.profile.mem_bw + cfg.profile.iter_overhead;
    cfg
}

fn cmd_simulate(argv: Vec<String>) -> i32 {
    let cli = Cli::new("econoserve simulate", "simulate a scheduler over a synthetic trace")
        .opt(
            "system",
            "econoserve",
            "system: '<sched>' or '<sched>+<alloc>' (see sched::all_systems and \
             kvc::all_allocators, e.g. vllm+exact); plus 'distserve'",
        )
        .opt("model", "opt-13b", "model profile: opt-13b | llama-33b | opt-175b")
        .opt("trace", "sharegpt", "trace: alpaca | sharegpt | bookcorpus")
        .opt("rate", "0", "arrival rate req/s (0 = trace default)")
        .opt("duration", "120", "trace duration, simulated seconds")
        .opt("seed", "42", "rng seed")
        .flag("oracle", "use ground-truth response lengths");
    let a = match cli.parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let trace_name = a.get("trace");
    let cfg = calibrated_cfg(a.get("model"), trace_name);
    let spec = TraceSpec::by_name(trace_name).expect("unknown trace");
    let rate = if a.f64("rate") > 0.0 { a.f64("rate") } else { spec.default_rate };
    let gen = TraceGen::new(spec);
    let items =
        gen.generate_for(a.f64("duration"), rate, cfg.profile.max_total_len, a.u64("seed"));
    println!(
        "simulate: system={} model={} trace={trace_name} rate={rate}/s n={} oracle={}",
        a.get("system"),
        cfg.profile.name,
        items.len(),
        a.bool("oracle")
    );
    let sys = a.get("system");
    if sys == "distserve" {
        let dcfg = DistServeConfig::homogeneous(cfg.profile.clone(), &cfg);
        let res = DistServeSim::new(dcfg).run(&items, a.f64("duration") * 10.0);
        print_summary(&res.summary, res.summary.n_total);
        println!("  transfer share of JCT: {:.1}%", res.transfer_share * 100.0);
        return 0;
    }
    let res = harness::simulate(
        &cfg,
        sys,
        trace_name,
        &items,
        a.bool("oracle"),
        RunLimits::for_time(a.f64("duration") * 10.0),
    );
    print_summary(&res.summary, items.len());
    println!("  wall time: {:.2}s ({} iterations)", res.wall_time, res.summary.iterations);
    0
}

// (allocation breakdown printed via ECONO_DEBUG inside harness if needed)

fn print_summary(s: &econoserve::metrics::Summary, n: usize) {
    println!(
        "  done {}/{n}  throughput {:.2} req/s ({:.0} tok/s)\n  \
         JCT mean {:.3}s [p5 {:.3} p95 {:.3}]  norm-latency {:.4} s/token\n  \
         SSR {:.1}%  TBT mean {:.4}s  wait {:.3}s exec {:.3}s preempt {:.3}s\n  \
         GPU util {:.1}%  KVC util {:.1}% (alloc {:.1}%)  fwd {:.0} tok  \
         alloc-fail {:.1}%  preemptions {}",
        s.n_done,
        s.throughput_rps,
        s.throughput_tps,
        s.mean_jct,
        s.p5_jct,
        s.p95_jct,
        s.norm_latency,
        s.ssr * 100.0,
        s.mean_tbt,
        s.mean_wait,
        s.mean_exec,
        s.mean_preempt,
        s.gpu_util * 100.0,
        s.kvc_util * 100.0,
        s.kvc_alloc * 100.0,
        s.avg_forward_size,
        s.alloc_failure_frac * 100.0,
        s.preemptions,
    );
}

fn cmd_sweep(argv: Vec<String>) -> i32 {
    let cli = Cli::new(
        "econoserve sweep",
        "parallel experiment grid: fan independent cells (system x model x trace x rate x \
         seed [x router x autoscaler x faults x guardrails]) over worker threads; JSON \
         spec in, one JSON row per cell out, bit-identical at any thread count",
    )
    .opt(
        "grid",
        "",
        "JSON grid-spec file (keys: systems, models, traces, rates, rate_points, seeds, \
         routers, autoscalers, faults, guardrails, predictor_faults, headroom, replicas, \
         duration, max_time, oracle, threads); when set, the inline axis options below \
         are ignored",
    )
    .opt("systems", "econoserve", "comma list of systems ('<sched>' or '<sched>+<alloc>')")
    .opt("model", "opt-13b", "comma list of model profiles")
    .opt("trace", "sharegpt", "comma list of traces")
    .opt("rates", "", "comma list of arrival rates req/s (empty = capacity-scaled auto grid)")
    .opt("rate-points", "4", "points in the auto rate grid when --rates is empty")
    .opt("seeds", "42", "comma list of workload seeds")
    .opt("routers", "", "comma list of fleet routers (set with --autoscalers for fleet cells)")
    .opt("autoscalers", "", "comma list of fleet autoscalers")
    .opt("faults", "", "comma list of fault profiles for fleet cells (empty = fault-free)")
    .opt(
        "guardrails",
        "",
        "comma list of reliability guardrail modes for fleet cells, e.g. off,retry+hedge \
         (empty = off)",
    )
    .opt(
        "predictor-faults",
        "",
        "comma list of predictor fault profiles, e.g. none,regime-shift (empty = none); \
         works for single AND fleet cells",
    )
    .opt(
        "headroom",
        "",
        "comma list of KVC padding modes, e.g. static,adaptive (empty = static); works \
         for single AND fleet cells",
    )
    .opt("replicas", "2", "fleet size bound for fleet cells")
    .opt("duration", "30", "workload duration, simulated seconds")
    .opt("max-time", "900", "simulated-time cap (drain allowance)")
    .opt("threads", "0", "worker threads (0 = ECONOSERVE_THREADS, then available parallelism)")
    .opt("out", "", "write the result JSON here (empty = stdout)")
    .opt(
        "metrics-out",
        "",
        "write the merged telemetry registry (Prometheus text, all cells in grid order) here",
    )
    .opt(
        "trace-out",
        "",
        "write the merged lifecycle trace (all cells in grid order, pids banded per cell) \
         here; '.jsonl' extension selects JSONL, anything else Chrome trace-event JSON",
    )
    .flag("oracle", "use ground-truth response lengths");
    let a = match cli.parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let grid_path = a.get("grid");
    let mut spec = if !grid_path.is_empty() {
        match Json::parse_file(grid_path).and_then(|doc| GridSpec::from_json(&doc)) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("bad grid spec {grid_path}: {e}");
                return 2;
            }
        }
    } else {
        let mut seeds = Vec::new();
        for s in a.str_list("seeds") {
            match s.parse::<u64>() {
                Ok(v) => seeds.push(v),
                Err(_) => {
                    eprintln!("--seeds: bad integer '{s}'");
                    return 2;
                }
            }
        }
        let spec = GridSpec {
            systems: a.str_list("systems"),
            models: a.str_list("model"),
            traces: a.str_list("trace"),
            rates: a.f64_list("rates"),
            rate_points: a.usize("rate-points"),
            seeds,
            routers: a.str_list("routers"),
            autoscalers: a.str_list("autoscalers"),
            faults: a.str_list("faults"),
            guardrails: a.str_list("guardrails"),
            predictor_faults: a.str_list("predictor-faults"),
            headroom: a.str_list("headroom"),
            replicas: a.usize("replicas"),
            duration: a.f64("duration"),
            max_time: a.f64("max-time"),
            oracle: a.bool("oracle"),
            threads: a.usize("threads"),
            trace: false,
        };
        if let Err(e) = spec.validate() {
            eprintln!("bad sweep spec: {e}");
            return 2;
        }
        spec
    };
    // --trace-out implies tracing even when the grid file left it off.
    if !a.get("trace-out").is_empty() {
        spec.trace = true;
    }
    // Progress on stderr: stdout stays pure JSON when --out is empty.
    let n_cells = spec.cells().len();
    eprintln!(
        "sweep: {n_cells} cells on {} thread(s)",
        exp::resolve_threads(spec.threads).min(n_cells.max(1))
    );
    let res = exp::run_grid(&spec);
    let doc = res.to_json().to_string();
    let out = a.get("out");
    if out.is_empty() {
        println!("{doc}");
    } else if let Err(e) = std::fs::write(out, &doc) {
        eprintln!("write {out}: {e}");
        return 1;
    } else {
        println!(
            "sweep: {} cells in {:.2}s on {} thread(s) -> {out}",
            res.rows.len(),
            res.wall_s,
            res.threads
        );
    }
    let metrics_out = a.get("metrics-out");
    if !metrics_out.is_empty() {
        if let Err(e) = std::fs::write(metrics_out, &res.metrics) {
            eprintln!("write {metrics_out}: {e}");
            return 1;
        }
        eprintln!("sweep: telemetry -> {metrics_out}");
    }
    let trace_out = a.get("trace-out");
    if !trace_out.is_empty() {
        let Some(doc) = res.trace.as_ref() else {
            eprintln!("sweep: no trace collected (internal error)");
            return 1;
        };
        if let Err(e) = write_trace(doc, trace_out) {
            eprintln!("write {trace_out}: {e}");
            return 1;
        }
        eprintln!("sweep: trace ({} events) -> {trace_out}", doc.events.len());
    }
    0
}

/// Write a trace document: Chrome trace-event JSON (Perfetto-loadable)
/// by default, JSONL when the path ends in `.jsonl`.
fn write_trace(doc: &TraceDoc, path: &str) -> std::io::Result<()> {
    let text = if path.ends_with(".jsonl") { doc.to_jsonl() } else { doc.to_chrome_string() };
    std::fs::write(path, text)
}

/// The simulation stack is std-only; only `serve` needs the native
/// PJRT/xla toolchain, so the binary builds (and every other subcommand
/// runs) under `--no-default-features`.
#[cfg(not(feature = "pjrt"))]
fn cmd_serve(_argv: Vec<String>) -> i32 {
    eprintln!(
        "econoserve serve needs the real-model runtime: rebuild with the \
         'pjrt' feature (the default) instead of --no-default-features"
    );
    2
}

#[cfg(feature = "pjrt")]
fn cmd_serve(argv: Vec<String>) -> i32 {
    use econoserve::api::{AdmissionConfig, SubmitOptions};
    use econoserve::ordering::QueuePolicy;
    use econoserve::server::{RealServer, ServerConfig};
    use econoserve::util::rng::Rng;

    let cli = Cli::new("econoserve serve", "serve a workload on the REAL model via PJRT")
        .opt("artifacts", "artifacts", "AOT artifacts directory")
        .opt("listen", "", "start the HTTP front-end on this address (e.g. 127.0.0.1:8080) instead of the batch demo")
        .opt("requests", "32", "number of requests")
        .opt("prompt-len", "24", "mean prompt length (tokens)")
        .opt("max-new", "48", "mean response length (tokens)")
        .opt("ordering", "econoserve", "queue ordering policy: econoserve | fcfs")
        .opt("max-inflight", "256", "admission bound on requests in flight (0 = unbounded)")
        .opt("rate-limit", "0", "per-key sustained request rate per second (0 = off)")
        .opt("burst", "8", "rate-limiter burst capacity (with --rate-limit)")
        .opt("seed", "7", "rng seed");
    let a = match cli.parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let Some(ordering) = QueuePolicy::by_name(a.get("ordering")) else {
        eprintln!(
            "unknown ordering '{}' (expected one of {:?})",
            a.get("ordering"),
            QueuePolicy::names()
        );
        return 2;
    };
    let server_cfg = ServerConfig {
        ordering,
        admission: AdmissionConfig { max_inflight: a.usize("max-inflight"), ..Default::default() },
        rate_limit: if a.f64("rate-limit") > 0.0 {
            econoserve::api::RateLimitConfig::per_key(a.f64("rate-limit"), a.f64("burst"))
        } else {
            econoserve::api::RateLimitConfig::default()
        },
    };
    let listen = a.get("listen").to_string();
    if !listen.is_empty() {
        match econoserve::server::http::HttpServer::start_with(
            &listen,
            a.get("artifacts"),
            server_cfg,
        ) {
            Ok(srv) => {
                println!(
                    "serving on http://{} (ordering={})\n  POST /v1/generate    {{\"prompt\": [ids], \"max_new_tokens\": n}}\n  POST /v1/stream      same body, chunked NDJSON token stream\n  POST /v1/completions OpenAI-compatible (string prompt, optional SSE)\n  GET  /v1/models | /v1/stats | /v1/info | /metrics | /health",
                    srv.addr,
                    ordering.name()
                );
                // Run until killed.
                loop {
                    std::thread::sleep(std::time::Duration::from_secs(3600));
                }
            }
            Err(e) => {
                eprintln!("failed to start server: {e:#}");
                return 1;
            }
        }
    }
    let model = match econoserve::runtime::PjrtModel::load(a.get("artifacts")) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("failed to load artifacts: {e:#}\nrun `make artifacts` first");
            return 1;
        }
    };
    println!(
        "loaded {} params, slots={}, max_seq={}",
        model.dims.param_count, model.dims.decode_slots, model.dims.max_seq
    );
    let dims = model.dims.clone();
    let mut server = RealServer::with_config(model, server_cfg);
    let mut rng = Rng::new(a.u64("seed"));
    let n = a.usize("requests");
    for _ in 0..n {
        let plen = rng.range_usize(4, (a.usize("prompt-len") * 2).min(dims.max_prompt));
        let rl = rng.range_usize(4, a.usize("max-new") * 2).min(dims.max_seq - plen - 2);
        let prompt: Vec<i32> =
            (0..plen).map(|_| rng.range_u64(1, dims.vocab as u64 - 1) as i32).collect();
        match server.submit(SubmitOptions::new(prompt, rl.max(1)).with_predicted_rl(rl as u32)) {
            // Fire-and-forget: completions are read from the server.
            Ok(handle) => handle.detach(),
            Err(e) => eprintln!("rejected: {e}"),
        }
    }
    if let Err(e) = server.run_to_completion() {
        eprintln!("serving failed: {e:#}");
        return 1;
    }
    let st = server.stats();
    println!(
        "served {} requests ({} rejected, {} cancelled): {:.2} req/s, {:.1} tok/s\n\
         latency mean {:.3}s p95 {:.3}s  ttft {:.3}s  tbt {:.4}s\n\
         decode iterations {}  mean batch occupancy {:.2}/{}",
        st.completed,
        st.rejected,
        st.cancelled,
        st.throughput_rps,
        st.throughput_tps,
        st.mean_latency,
        st.p95_latency,
        st.mean_ttft,
        st.mean_tbt,
        st.decode_iterations,
        st.mean_batch_occupancy,
        dims.decode_slots,
    );
    0
}

fn cmd_trace(argv: Vec<String>) -> i32 {
    let cli = Cli::new("econoserve trace", "generate / inspect synthetic traces")
        .opt("trace", "sharegpt", "alpaca | sharegpt | bookcorpus")
        .opt("n", "10000", "number of requests")
        .opt("rate", "0", "arrival rate (0 = default)")
        .opt("seed", "42", "rng seed")
        .opt("out", "", "write CSV to this path");
    let a = match cli.parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let spec = TraceSpec::by_name(a.get("trace")).expect("unknown trace");
    let rate = if a.f64("rate") > 0.0 { a.f64("rate") } else { spec.default_rate };
    let gen = TraceGen::new(spec);
    let items = gen.generate(a.usize("n"), rate, 4096, a.u64("seed"));
    let s = trace::stats(&items);
    println!(
        "{}: n={} | input avg {:.1} [{}..{}] (paper {:.1} [{}..{}]) | \
         output avg {:.1} [{}..{}] (paper {:.1} [{}..{}]) | rate {:.2}/s",
        spec.name,
        s.n,
        s.in_avg,
        s.in_min,
        s.in_max,
        spec.input.avg,
        spec.input.min,
        spec.input.max,
        s.out_avg,
        s.out_min,
        s.out_max,
        spec.output.avg,
        spec.output.min,
        spec.output.max,
        s.rate
    );
    let out = a.get("out");
    if !out.is_empty() {
        if let Err(e) = trace::save_csv(&items, out) {
            eprintln!("write {out}: {e}");
            return 1;
        }
        println!("wrote {out}");
    }
    0
}

fn cmd_capacity(argv: Vec<String>) -> i32 {
    let cli = Cli::new(
        "econoserve capacity",
        "min GPUs for EconoServe to match DistServe goodput (Fig 12)",
    )
    .opt("model", "opt-13b", "model profile")
    .opt("rate", "4", "arrival rate req/s")
    .opt("duration", "120", "trace duration (simulated s)")
    .opt("seed", "42", "rng seed")
    .flag("heterogeneous", "H100 prefill + A100 decode for DistServe");
    let a = match cli.parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let cfg = calibrated_cfg(a.get("model"), "sharegpt");
    let gen = TraceGen::new(TraceSpec::sharegpt());
    let items = gen.generate_for(
        a.f64("duration"),
        a.f64("rate"),
        cfg.profile.max_total_len,
        a.u64("seed"),
    );
    let dcfg = if a.bool("heterogeneous") {
        DistServeConfig::heterogeneous(cfg.profile.clone(), &cfg)
    } else {
        DistServeConfig::homogeneous(cfg.profile.clone(), &cfg)
    };
    let dist = DistServeSim::new(dcfg).run(&items, a.f64("duration") * 10.0);
    let dist_gpus = 2 * cfg.profile.gpus_per_replica;
    println!(
        "DistServe: goodput {:.2} req/s on {} GPUs (SSR {:.1}%)",
        dist.goodput,
        dist_gpus,
        dist.summary.ssr * 100.0
    );
    match fleet::min_replicas_for_goodput(
        &cfg,
        "econoserve",
        "sharegpt",
        &items,
        false,
        dist.goodput,
        8,
        a.f64("duration") * 10.0,
    ) {
        Some(k) => {
            let gpus = k * cfg.profile.gpus_per_replica as usize;
            println!(
                "EconoServe: {k} replica(s) = {gpus} GPU(s) for the same goodput \
                 ({:.0}% fewer than DistServe)",
                (1.0 - gpus as f64 / dist_gpus as f64) * 100.0
            );
        }
        None => println!("EconoServe: target goodput not reachable within 8 replicas"),
    }
    0
}

fn cmd_fleet(argv: Vec<String>) -> i32 {
    let cli = Cli::new(
        "econoserve fleet",
        "event-driven multi-replica fleet: routing, autoscaling, GPU-hour cost",
    )
    .opt("system", "econoserve", "scheduler system ('<sched>' or '<sched>+<alloc>')")
    .opt("model", "opt-13b", "model profile: opt-13b | llama-33b | opt-175b")
    .opt("trace", "sharegpt", "trace: alpaca | sharegpt | bookcorpus")
    .opt("workload", "diurnal", "arrival process: poisson | mmpp | diurnal")
    .opt("rate", "0", "mean arrival rate req/s (0 = 40% of the max-fleet capacity estimate)")
    .opt("router", "least-kvc", "router: round-robin | least-queue | least-kvc | power-of-two")
    .opt("autoscaler", "reactive", "autoscaler: static-k | reactive | forecast")
    .opt("replicas", "2", "initial replicas")
    .opt("min", "1", "minimum serving replicas")
    .opt("max", "4", "maximum serving replicas")
    .opt("boot-latency", "8", "seconds from scale-up decision to a routable replica")
    .opt("control-interval", "5", "seconds between autoscaler control ticks")
    .opt("duration", "600", "workload duration, simulated seconds")
    .opt("seed", "42", "rng seed (per-replica streams are derived from it)")
    .opt(
        "chaos",
        "none",
        "fault profile (none | crashes | zone-outage | stragglers | flaky-boots | \
         full-chaos); when not 'none', compares every router's goodput/SSR retention \
         under the profile against its own fault-free baseline",
    )
    .opt(
        "guardrails",
        "off",
        "reliability guardrails: off | full | '+'-joined {retry, hedge, abort, brownout} \
         (e.g. retry+hedge); when not 'off' in plain mode, an off-guardrails reference \
         run is printed alongside for comparison",
    )
    .opt(
        "predictor-bias",
        "1",
        "multiplicative RL-predictor bias (< 1 systematically under-predicts, > 1 \
         over-predicts; 1 = calibrated)",
    )
    .opt(
        "predictor-faults",
        "none",
        "predictor fault profile (none | bias-drift | heavy-tail | regime-shift | outage | \
         full-chaos); timelines are seeded from the dedicated predictor rng stream, so \
         enabling them never perturbs the workload/router/chaos streams",
    )
    .opt(
        "headroom",
        "static",
        "KVC padding mode: static (the per-trace sweet-spot constant) | adaptive (online \
         misprediction tracker steers the padding ratio and bounds per-iteration \
         overrun evictions)",
    )
    .opt(
        "metrics-out",
        "",
        "write the fleet's merged telemetry registry (Prometheus text) here \
         (in --chaos comparison mode: the telemetry of one run under the profile \
         with the configured router and guardrails)",
    )
    .opt(
        "trace-out",
        "",
        "write the request-lifecycle trace here (same run as --metrics-out); '.jsonl' \
         extension selects JSONL, anything else Chrome trace-event JSON (Perfetto-loadable)",
    )
    .opt(
        "trace-sample",
        "1",
        "head-sampling fraction for per-request spans in --trace-out (0..=1, seeded, \
         content-keyed: identical across runs/threads; aggregate counts stay exact)",
    )
    .opt(
        "log-out",
        "",
        "write the bounded per-replica request logs (JSONL, one object per event with a \
         'replica' tag) here",
    )
    .flag("oracle", "use ground-truth response lengths")
    .flag(
        "compare-static",
        "also run a static peak fleet at --max replicas and print the cost delta",
    );
    let a = match cli.parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    if a.f64("control-interval") <= 0.0 {
        eprintln!("--control-interval must be positive");
        return 2;
    }
    let max_replicas = a.usize("max").max(1);
    let min_replicas = a.usize("min").max(1);
    if min_replicas > max_replicas {
        eprintln!("--min ({min_replicas}) must be <= --max ({max_replicas})");
        return 2;
    }
    let trace_name = a.get("trace");
    let mut cfg = calibrated_cfg(a.get("model"), trace_name);
    cfg.seed = a.u64("seed");
    let pf_name = a.get("predictor-faults");
    if econoserve::predictor::faults::by_name(pf_name).is_none() {
        eprintln!(
            "unknown predictor fault profile '{pf_name}' (expected one of {:?})",
            econoserve::predictor::faults::all_profiles()
        );
        return 2;
    }
    let headroom_name = a.get("headroom");
    if econoserve::reliability::headroom::HeadroomConfig::parse(headroom_name).is_none() {
        eprintln!(
            "unknown headroom mode '{headroom_name}' (expected one of {:?})",
            econoserve::reliability::headroom::HeadroomConfig::all_modes()
        );
        return 2;
    }
    let bias = a.f64("predictor-bias");
    if bias <= 0.0 {
        eprintln!("--predictor-bias must be positive");
        return 2;
    }
    cfg.predictor_bias = bias;
    cfg.predictor_faults = pf_name.to_string();
    cfg.headroom = headroom_name.to_string();
    let spec = TraceSpec::by_name(trace_name).expect("unknown trace");
    let cap = cfg.capacity_estimate(&spec);
    let mean_rate =
        if a.f64("rate") > 0.0 { a.f64("rate") } else { 0.4 * cap * max_replicas as f64 };
    let Some(mut process) = ArrivalProcess::by_name(a.get("workload"), mean_rate) else {
        eprintln!(
            "unknown workload '{}' (expected one of {:?})",
            a.get("workload"),
            ArrivalProcess::names()
        );
        return 2;
    };
    let duration = a.f64("duration");
    if let ArrivalProcess::Diurnal { ref mut period, .. } = process {
        // Snap the day-curve so the run covers a whole number of
        // periods: the realized mean rate then equals the configured
        // mean (a fractional final period would skew offered load vs
        // the poisson/mmpp workloads at the same --rate).
        let cycles = (duration / *period).round().max(1.0);
        *period = duration / cycles;
    }
    let gen = TraceGen::new(spec);
    let items = gen.generate_arrivals(process, duration, cfg.profile.max_total_len, cfg.seed);
    let mut fc = FleetConfig::new(cfg.clone(), a.get("system"), trace_name);
    fc.oracle = a.bool("oracle");
    fc.router = a.get("router").to_string();
    fc.autoscaler = a.get("autoscaler").to_string();
    fc.init_replicas = a.usize("replicas");
    fc.min_replicas = min_replicas;
    fc.max_replicas = max_replicas;
    fc.boot_latency = a.f64("boot-latency");
    fc.control_interval = a.f64("control-interval");
    fc.max_sim_time = duration * 4.0;
    let chaos_name = a.get("chaos");
    let Some(profile) = econoserve::fleet::faults::by_name(chaos_name) else {
        eprintln!(
            "unknown fault profile '{chaos_name}' (expected one of {:?})",
            econoserve::fleet::all_profiles()
        );
        return 2;
    };
    let guard_name = a.get("guardrails");
    if econoserve::reliability::GuardrailConfig::parse(guard_name).is_none() {
        eprintln!(
            "unknown guardrail mode '{guard_name}' (expected 'off', 'full', or \
             '+'-joined {{retry, hedge, abort, brownout}})"
        );
        return 2;
    }
    fc.guardrails = guard_name.to_string();
    let trace_out = a.get("trace-out");
    let log_out = a.get("log-out");
    let sample = a.f64("trace-sample");
    if !(0.0..=1.0).contains(&sample) {
        eprintln!("--trace-sample must be in 0..=1");
        return 2;
    }
    if !trace_out.is_empty() {
        // The trace rng stream is derived from the workload seed, so the
        // same seed yields the same sampled request set at any sample < 1.
        fc.tracing =
            Some(TraceConfig::new(derive_seed(cfg.seed, stream::TRACE)).with_sample(sample));
    }
    if !log_out.is_empty() {
        fc.reqlog_capacity = 4096;
    }
    if profile.is_active() {
        fc.faults = chaos_name.to_string();
        println!(
            "fleet chaos: profile={chaos_name} guardrails={guard_name} system={} \
             trace={trace_name} workload={} (mean {mean_rate:.2}/s) autoscaler={} \
             replicas {}..{} n={}",
            fc.system,
            a.get("workload"),
            fc.autoscaler,
            fc.min_replicas,
            fc.max_replicas,
            items.len()
        );
        println!(
            "  {:<14} {:>9} {:>9} {:>8} {:>9} {:>8} {:>6} {:>7} {:>6} {:>6}",
            "router",
            "gput-ret%",
            "ssr-ret%",
            "crashes",
            "bootfail",
            "rerouted",
            "lost",
            "retried",
            "recov",
            "ssr%"
        );
        for router in econoserve::fleet::all_routers() {
            let mut rc = fc.clone();
            rc.router = router.to_string();
            // Artifacts come from the dedicated run below, not the
            // comparison table's many fleets.
            rc.tracing = None;
            rc.reqlog_capacity = 0;
            let out = fleet::chaos_run(&rc, &items);
            let f = &out.chaos.faults;
            println!(
                "  {:<14} {:>9.1} {:>9.1} {:>8} {:>9} {:>8} {:>6} {:>7} {:>6} {:>6.1}",
                router,
                out.goodput_retention() * 100.0,
                out.ssr_retention() * 100.0,
                f.crashes,
                f.boot_failures,
                f.rerouted,
                f.lost,
                f.retried,
                f.recovered,
                out.chaos.ssr * 100.0,
            );
        }
        // Health-blind reference: same chaos, but corpses stay in the
        // routing table and losses are never re-provisioned.
        let mut bc = fc.clone();
        bc.health_aware = false;
        bc.tracing = None;
        bc.reqlog_capacity = 0;
        let blind = fleet::chaos_run(&bc, &items);
        println!(
            "  {:<14} {:>9.1} {:>9.1}   (router={}, corpses look routable, losses unseen)",
            "health-blind",
            blind.goodput_retention() * 100.0,
            blind.ssr_retention() * 100.0,
            fc.router,
        );
        let metrics_out = a.get("metrics-out");
        if !metrics_out.is_empty() || !trace_out.is_empty() || !log_out.is_empty() {
            // One more run with the configured router + guardrails under
            // the profile: its merged telemetry/trace/log are the exported
            // artifacts (the comparison table above runs many fleets).
            let res = fleet::run(&fc, &items);
            if !metrics_out.is_empty() {
                if let Err(e) = std::fs::write(metrics_out, &res.metrics) {
                    eprintln!("write {metrics_out}: {e}");
                    return 1;
                }
                println!(
                    "  telemetry (router={}, guardrails={guard_name}) -> {metrics_out}",
                    fc.router
                );
            }
            let code = write_fleet_artifacts(&res, trace_out, log_out);
            if code != 0 {
                return code;
            }
        }
        return 0;
    }
    println!(
        "fleet: system={} trace={trace_name} workload={} (mean {mean_rate:.2}/s, peak \
         {:.2}/s) router={} autoscaler={} replicas {}..{} n={}",
        fc.system,
        a.get("workload"),
        process.peak_rate(),
        fc.router,
        fc.autoscaler,
        fc.min_replicas,
        fc.max_replicas,
        items.len()
    );
    let res = fleet::run(&fc, &items);
    let metrics_out = a.get("metrics-out");
    if !metrics_out.is_empty() {
        if let Err(e) = std::fs::write(metrics_out, &res.metrics) {
            eprintln!("write {metrics_out}: {e}");
            return 1;
        }
        println!("  telemetry -> {metrics_out}");
    }
    let code = write_fleet_artifacts(&res, trace_out, log_out);
    if code != 0 {
        return code;
    }
    print_fleet_summary(a.get("autoscaler"), &res.summary);
    for (id, log) in res.replicas.iter().enumerate() {
        println!(
            "    replica {id}: routed {}  routable {:.1}s{}{}{}",
            log.routed,
            log.routable_at,
            log.drain_at.map(|t| format!("  drained {t:.1}s")).unwrap_or_default(),
            log.retired_at.map(|t| format!("  retired {t:.1}s")).unwrap_or_default(),
            log.crashed_at.map(|t| format!("  crashed {t:.1}s")).unwrap_or_default(),
        );
    }
    if econoserve::reliability::GuardrailConfig::parse(guard_name)
        .is_some_and(|g| g.is_active())
    {
        // Reference run with guardrails off: same fleet, same workload,
        // same fault/router/autoscaler streams (the guardrail rng is a
        // dedicated stream, so the comparison is apples to apples).
        let mut oc = fc.clone();
        oc.guardrails = "off".to_string();
        oc.tracing = None;
        oc.reqlog_capacity = 0;
        let off = fleet::run(&oc, &items);
        print_fleet_summary("guardrails-off", &off.summary);
        let s = &res.summary;
        let b = &off.summary;
        println!(
            "  guardrails={guard_name} vs off: goodput {:+.2} req/s, SSR {:+.1}pp, \
             lost {} vs {}, retried {} recovered {} hedges won {} aborted {}",
            s.goodput_rps - b.goodput_rps,
            (s.ssr - b.ssr) * 100.0,
            s.faults.lost,
            b.faults.lost,
            s.faults.retried,
            s.faults.recovered,
            s.faults.hedges_won,
            s.faults.aborted,
        );
    }
    if a.bool("compare-static") {
        let mut sc = fc.clone();
        sc.tracing = None;
        sc.reqlog_capacity = 0;
        sc.autoscaler = "static-k".to_string();
        sc.init_replicas = max_replicas;
        sc.min_replicas = max_replicas;
        sc.boot_latency = 0.0;
        let st = fleet::run(&sc, &items);
        print_fleet_summary("static-peak", &st.summary);
        let s = &res.summary;
        let b = &st.summary;
        println!(
            "  {} vs static-peak: SSR {:+.1}pp, GPU-hours {:.2} vs {:.2} ({:.0}% fewer), \
             goodput/GPU-h {:.1} vs {:.1}",
            fc.autoscaler,
            (s.ssr - b.ssr) * 100.0,
            s.gpu_hours,
            b.gpu_hours,
            (1.0 - s.gpu_hours / b.gpu_hours.max(1e-9)) * 100.0,
            s.goodput_per_gpu_hour,
            b.goodput_per_gpu_hour,
        );
    }
    0
}

fn print_fleet_summary(label: &str, s: &econoserve::fleet::FleetSummary) {
    println!(
        "  [{label}] done {}/{} (routed {})  goodput {:.2} req/s  SSR {:.1}%\n  \
         JCT mean {:.3}s p95 {:.3}s  span {:.1}s\n  \
         GPU-hours {:.3}  goodput/GPU-h {:.1}  replicas peak {} floor {} mean {:.2}  \
         boots {} retirements {}",
        s.n_done,
        s.n_total,
        s.n_routed,
        s.goodput_rps,
        s.ssr * 100.0,
        s.mean_jct,
        s.p95_jct,
        s.end_time,
        s.gpu_hours,
        s.goodput_per_gpu_hour,
        s.peak_replicas,
        s.floor_replicas,
        s.mean_replicas,
        s.boots,
        s.retirements,
    );
    if !s.faults.is_zero() {
        let f = &s.faults;
        println!(
            "  faults: crashes {} (zone outages {})  stragglers {}  boot failures {}  \
             rerouted {}  lost {}",
            f.crashes, f.zone_outages, f.stragglers, f.boot_failures, f.rerouted, f.lost,
        );
        if f.retried + f.recovered + f.hedges_won + f.aborted > 0 {
            println!(
                "  guardrails: retried {}  recovered {}  hedges won {}  aborted {}",
                f.retried, f.recovered, f.hedges_won, f.aborted,
            );
        }
    }
}

fn cmd_promlint(argv: Vec<String>) -> i32 {
    use econoserve::telemetry::Snapshot;

    let cli = Cli::new(
        "econoserve promlint",
        "strict-parse a Prometheus text file and verify canonical form: every sample \
         must belong to a typed family, and the file must re-render byte-identically \
         (the form every --metrics-out writer and GET /metrics produces)",
    )
    .opt("file", "", "exposition text file to lint (required)");
    let a = match cli.parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let path = a.get("file");
    if path.is_empty() {
        eprintln!("promlint: --file is required");
        return 2;
    }
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("promlint: read {path}: {e}");
            return 1;
        }
    };
    let snap = match Snapshot::parse(&text) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("promlint: {path}: {e}");
            return 1;
        }
    };
    if snap.render() != text {
        eprintln!(
            "promlint: {path}: parses but is not in canonical form \
             (families/labels out of canonical order?)"
        );
        return 1;
    }
    println!(
        "promlint: {path}: OK ({} families, {} samples)",
        snap.family_names().len(),
        snap.sample_count()
    );
    0
}

/// Write the `--trace-out` / `--log-out` artifacts of a fleet run.
fn write_fleet_artifacts(res: &fleet::FleetResult, trace_out: &str, log_out: &str) -> i32 {
    if !trace_out.is_empty() {
        let Some(doc) = res.trace_doc.as_ref() else {
            eprintln!("fleet: no trace collected (internal error)");
            return 1;
        };
        if let Err(e) = write_trace(doc, trace_out) {
            eprintln!("write {trace_out}: {e}");
            return 1;
        }
        println!("  trace ({} events, sample {}) -> {trace_out}", doc.events.len(), doc.sample);
    }
    if !log_out.is_empty() {
        let text = res.reqlog.as_deref().unwrap_or("");
        if let Err(e) = std::fs::write(log_out, text) {
            eprintln!("write {log_out}: {e}");
            return 1;
        }
        println!("  request log ({} lines) -> {log_out}", text.lines().count());
    }
    0
}

fn cmd_tracelint(argv: Vec<String>) -> i32 {
    let cli = Cli::new(
        "econoserve tracelint",
        "validate a lifecycle trace written by `fleet`/`sweep --trace-out` (Chrome \
         trace-event JSON or JSONL): every request track's spans must partition \
         [submit, finish] with no overlap or gap on the sim clock, terminal outcomes \
         must be unique, and (at sample >= 1) the per-track span census must equal the \
         embedded aggregate outcome counters; with --metrics, span outcomes are also \
         reconciled against `econoserve_requests_total{outcome=...}`",
    )
    .opt("file", "", "trace file to lint (required)")
    .opt("metrics", "", "Prometheus text file from the SAME run to reconcile against");
    let a = match cli.parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let path = a.get("file");
    if path.is_empty() {
        eprintln!("tracelint: --file is required");
        return 2;
    }
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("tracelint: read {path}: {e}");
            return 1;
        }
    };
    let rep = match tracing::lint(&text) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("tracelint: {path}: {e}");
            return 1;
        }
    };
    let metrics_path = a.get("metrics");
    if !metrics_path.is_empty() {
        let mtext = match std::fs::read_to_string(metrics_path) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("tracelint: read {metrics_path}: {e}");
                return 1;
            }
        };
        if let Err(e) = tracing::reconcile(&rep, &mtext) {
            eprintln!("tracelint: {path} vs {metrics_path}: {e}");
            return 1;
        }
        println!("tracelint: outcomes reconcile with {metrics_path}");
    }
    let [done, rejected, cancelled, lost] = rep.meta_outcomes;
    println!(
        "tracelint: {path}: OK ({} events, {} request tracks, sample {}, dropped {})\n  \
         outcomes: done {done} rejected {rejected} cancelled {cancelled} lost {lost}",
        rep.events, rep.request_tracks, rep.sample, rep.dropped,
    );
    0
}

fn cmd_trace_report(argv: Vec<String>) -> i32 {
    let cli = Cli::new(
        "econoserve trace-report",
        "per-request time attribution from a lifecycle trace: each traced request's \
         lifetime split across queued / prefill / decode / stalled-on-KVC / preempted, \
         plus the per-scheduler skip-reason breakdown (kvc_exhausted vs batch_full vs \
         ordering vs waiting_held vs brownout_shed)",
    )
    .opt("file", "", "trace file to report on (required)");
    let a = match cli.parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let path = a.get("file");
    if path.is_empty() {
        eprintln!("trace-report: --file is required");
        return 2;
    }
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("trace-report: read {path}: {e}");
            return 1;
        }
    };
    match tracing::report(&text) {
        Ok(table) => {
            print!("{table}");
            0
        }
        Err(e) => {
            eprintln!("trace-report: {path}: {e}");
            1
        }
    }
}

fn cmd_figures(argv: Vec<String>) -> i32 {
    let cli = Cli::new("econoserve figures", "regenerate paper figures (same drivers as cargo bench)")
        .opt("only", "", "comma list of figures to run, e.g. 1,9,13 (default: all)")
        .flag("fast", "reduced durations/grids");
    let a = match cli.parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let fast = a.bool("fast");
    let only: Vec<String> = a.str_list("only");
    let want = |id: &str| only.is_empty() || only.iter().any(|x| x == id);
    use econoserve::figures as f;
    if want("1") {
        f::fig1::run(fast);
    }
    if want("2") {
        f::fig2::run_fig(fast);
    }
    if want("4") {
        f::fig4::run(fast);
    }
    if want("5") {
        f::fig5::run(fast);
    }
    if want("6") {
        f::fig6::run(fast);
    }
    if want("9") {
        f::fig9::run(fast);
    }
    if want("10") {
        f::fig10::run(fast);
    }
    if want("11") {
        f::fig11::run(fast);
    }
    if want("12") {
        f::fig12::run(fast);
    }
    if want("13") {
        f::fig13::run(fast);
    }
    if want("14") {
        f::fig14::run(fast);
    }
    if want("15") {
        f::fig15::run(fast);
    }
    0
}
