//! Request-lifecycle tests against the real PJRT engine, driven
//! synchronously through `RealServer::tick` so slot accounting is
//! deterministic: cancellation frees a decode slot mid-generation and
//! the freed slot is immediately handed to a queued request; admission
//! bounds the queue; stats anchor their time base at the first submit.
//! Requires `make artifacts` (skips loudly otherwise).

use econoserve::api::{AdmissionConfig, FinishReason, StreamEvent, SubmitOptions};
use econoserve::ordering::QueuePolicy;
use econoserve::runtime::PjrtModel;
use econoserve::server::{RealServer, ServerConfig};

fn artifacts() -> Option<String> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir.to_string_lossy().into_owned())
    } else {
        eprintln!("SKIP real_serving: run `make artifacts` first");
        None
    }
}

fn load(dir: &str) -> RealServer {
    RealServer::new(PjrtModel::load(dir).expect("load artifacts"))
}

#[test]
fn cancellation_frees_slot_and_queued_request_is_admitted() {
    let Some(dir) = artifacts() else { return };
    let mut server = load(&dir);
    let slots = server.dims().decode_slots;

    // Fill every decode slot with a long-running request.
    let mut streams = Vec::new();
    for i in 0..slots {
        let opts = SubmitOptions::new(vec![3 + i as i32, 4, 5], 10_000);
        streams.push(server.submit(opts).expect("admitted"));
    }
    server.tick().expect("tick");
    assert_eq!(server.live_slots(), slots, "all slots busy");

    // One more queues behind the full batch.
    let queued = server.submit(SubmitOptions::new(vec![9, 9, 9], 4)).expect("admitted");
    server.tick().expect("tick");
    assert_eq!(server.queue_len(), 1, "no slot free: the request must wait");

    // Cancel one in-flight stream: its slot is freed at the next
    // iteration boundary and the queued request takes it.
    streams[0].cancel();
    server.tick().expect("tick");
    assert_eq!(server.queue_len(), 0, "freed slot goes to the queued request");
    assert_eq!(server.live_slots(), slots, "slot reused, not leaked");

    // The cancelled stream terminates with FinishReason::Cancelled.
    let cancelled = streams.remove(0);
    let c = cancelled.wait().expect("terminal event delivered");
    assert_eq!(c.finish, FinishReason::Cancelled);
    assert!(!c.met_slo);

    // The queued request (4 tokens) runs to completion in the recycled
    // slot within a few more iterations.
    for _ in 0..8 {
        server.tick().expect("tick");
    }
    // Drain the queued handle's buffered events: it must have received
    // incremental tokens starting at index 0 and a successful terminal.
    let mut saw_first_token = false;
    let mut finish = None;
    while let Some(ev) = queued.try_recv() {
        match ev {
            StreamEvent::Token(t) => {
                if t.index == 0 {
                    saw_first_token = true;
                }
            }
            StreamEvent::Finished(c) => finish = Some(c.finish),
        }
    }
    assert!(saw_first_token, "queued request streamed from its first token");
    assert_eq!(finish, Some(FinishReason::Complete));

    // Engine-side accounting agrees.
    let stats = server.stats();
    assert_eq!(stats.cancelled, 1);
    assert!(stats.completed >= 1);

    // Remaining long streams: cancel them so the test ends quickly.
    for s in &streams {
        s.cancel();
    }
    server.tick().expect("tick");
    assert_eq!(server.stats().cancelled, 1 + streams.len());
}

#[test]
fn admission_bounds_inflight_on_real_path() {
    let Some(dir) = artifacts() else { return };
    let cfg = ServerConfig {
        ordering: QueuePolicy::EconoServe,
        admission: AdmissionConfig { max_inflight: 1, ..Default::default() },
        ..Default::default()
    };
    let mut server =
        RealServer::with_config(PjrtModel::load(&dir).expect("load artifacts"), cfg);

    let first = server.submit(SubmitOptions::new(vec![4, 5], 3)).expect("first fits");
    let err = server.submit(SubmitOptions::new(vec![6, 7], 3)).expect_err("bound hit");
    assert_eq!(err.http_status(), 429);
    assert_eq!(err.kind(), "queue_full");
    assert_eq!(err.finish_reason(), FinishReason::Rejected);

    server.run_to_completion().expect("drain");
    let c = first.wait().expect("completion");
    assert_eq!(c.finish, FinishReason::Complete);
    let stats = server.stats();
    assert_eq!(stats.completed, 1);
    assert_eq!(stats.rejected, 1);

    // The slot is free again: a new request is admitted.
    assert!(server.submit(SubmitOptions::new(vec![8, 9], 2)).is_ok());
    server.run_to_completion().expect("drain");
    assert_eq!(server.stats().completed, 2);
}

#[test]
fn stats_time_base_anchors_at_first_submit() {
    let Some(dir) = artifacts() else { return };
    let mut server = load(&dir);

    // Idle time before the first submit must NOT count against
    // throughput (the old code only reset the span inside
    // run_to_completion, so tick-driven use reported garbage).
    std::thread::sleep(std::time::Duration::from_secs(2));
    let h = server.submit(SubmitOptions::new(vec![11, 12, 13], 4)).expect("admitted");
    // Tick-driven (no run_to_completion): the span anchor still applies.
    while server.live_slots() > 0 || server.queue_len() > 0 {
        server.tick().expect("tick");
    }
    let c = h.wait().expect("completion");
    assert_eq!(c.finish, FinishReason::Complete);
    let stats = server.stats();
    assert_eq!(stats.completed, 1);
    assert!(
        stats.throughput_rps > 1.0 / 1.5,
        "span must start at first submit, not construction: {} req/s",
        stats.throughput_rps
    );
}
