//! Span-trace conservation properties: for every supported sched×alloc
//! registry combo, the lifecycle trace must partition each request's
//! lifetime exactly (lint's contiguity check), and the trace's aggregate
//! outcome totals must reconcile with `requests_total{outcome}` — under
//! plain runs, under sampling, and under full-chaos fleet runs with
//! retry+hedge guardrails.

use econoserve::config::{ModelProfile, SystemConfig};
use econoserve::coordinator::{harness, RunLimits};
use econoserve::telemetry::trace::{lint, reconcile, report};
use econoserve::telemetry::TraceConfig;
use econoserve::trace::TraceItem;
use econoserve::util::prop::sized;
use econoserve::util::rng::{derive_seed, stream, Rng};

/// Same mini profile as tests/equivalence.rs: opt-13b scaled down so
/// runs finish in milliseconds while still exercising KVC pressure.
fn mini_cfg(kvc_tokens: u64) -> SystemConfig {
    let mut profile = ModelProfile::opt_13b();
    profile.kvc_bytes = 819_200 * kvc_tokens;
    profile.max_total_len = 1024;
    let mut cfg = SystemConfig::new(profile);
    cfg.t_p = 0.05;
    cfg.t_g = 0.022;
    cfg.sched_time_scale = 0.0;
    cfg
}

fn random_items(rng: &mut Rng, n: usize, max_len: u32) -> Vec<TraceItem> {
    let mut t = 0.0;
    (0..n)
        .map(|_| {
            t += rng.exponential(5.0);
            let prompt_len = 1 + sized(rng, (max_len / 3) as usize) as u32;
            let true_rl = 1 + sized(rng, (max_len - prompt_len).min(300) as usize) as u32;
            TraceItem { arrival: t, prompt_len, true_rl }
        })
        .collect()
}

/// The supported sched×alloc grid (mirrors tests/equivalence.rs and
/// benches/sched_hotpath.rs).
fn supported_combos() -> Vec<String> {
    let mut combos = Vec::new();
    for (sched, allocs) in [
        ("orca", &["max", "pipelined-max"][..]),
        ("fastserve", &["max", "pipelined-max"][..]),
        ("vllm", &["block", "exact", "pipelined-block", "pipelined-exact"][..]),
        ("sarathi", &["block", "exact", "pipelined-block", "pipelined-exact"][..]),
        ("multires", &["exact", "pipelined-exact", "max"][..]),
        ("sync_coupled", &["exact", "pipelined-exact", "max"][..]),
        ("srtf", &["max", "pipelined-max"][..]),
        ("econoserve-d", &["exact"][..]),
        ("econoserve-sd", &["exact"][..]),
        ("econoserve-sdo", &["exact"][..]),
        ("econoserve", &["exact", "pipelined-exact", "max"][..]),
    ] {
        for a in allocs {
            combos.push(format!("{sched}+{a}"));
        }
    }
    combos
}

/// Every registry combo's trace must lint clean (exact lifetime
/// partition, one terminal per request) and reconcile with the run's
/// own `requests_total{outcome}` counters. The classification lives in
/// `IterCtx::finish_into`, so this is the pin that no scheduler escapes
/// the central emission path.
#[test]
fn every_combo_trace_partitions_lifecycles() {
    let mut rng = Rng::new(0x7AACE);
    let items = random_items(&mut rng, 25, 600);
    for combo in supported_combos() {
        let cfg = mini_cfg(4096);
        let tc = TraceConfig::new(derive_seed(cfg.seed, stream::TRACE));
        let res = harness::simulate_traced(
            &cfg,
            &combo,
            "sharegpt",
            &items,
            true,
            RunLimits::for_time(5_000.0),
            Some(tc),
        );
        let doc = res.trace.as_ref().expect("tracing was enabled");
        let text = doc.to_chrome_string();
        let rep = lint(&text).unwrap_or_else(|e| panic!("{combo}: lint failed: {e}"));
        assert!(rep.request_tracks > 0, "{combo}: no request tracks recorded");
        let total: u64 = rep.meta_outcomes.iter().sum();
        assert_eq!(total as usize, items.len(), "{combo}: outcome totals must cover every request");
        reconcile(&rep, &res.metrics).unwrap_or_else(|e| panic!("{combo}: reconcile failed: {e}"));
    }
}

/// Head sampling is an event-volume knob, never an accounting knob: the
/// aggregate outcome and skip totals are counted for ALL requests, so
/// they must be identical at sample 1.0 and sample 0.25, while the
/// per-request event volume shrinks.
#[test]
fn sampling_preserves_aggregates_and_shrinks_event_volume() {
    let mut rng = Rng::new(0x5a11);
    let items = random_items(&mut rng, 60, 600);
    let cfg = mini_cfg(4096);
    let run = |sample: f64| {
        let tc = TraceConfig::new(derive_seed(cfg.seed, stream::TRACE)).with_sample(sample);
        harness::simulate_traced(
            &cfg,
            "econoserve",
            "sharegpt",
            &items,
            true,
            RunLimits::for_time(5_000.0),
            Some(tc),
        )
    };
    let full = run(1.0);
    let part = run(0.25);
    let fdoc = full.trace.as_ref().unwrap();
    let pdoc = part.trace.as_ref().unwrap();
    let frep = lint(&fdoc.to_chrome_string()).expect("full trace lints");
    let prep = lint(&pdoc.to_chrome_string()).expect("sampled trace lints");
    assert_eq!(
        frep.meta_outcomes, prep.meta_outcomes,
        "aggregate outcome totals must be sampling-independent"
    );
    assert_eq!(fdoc.skips, pdoc.skips, "skip-reason totals must be sampling-independent");
    assert!(
        prep.request_tracks < frep.request_tracks,
        "0.25 sampling must trace fewer requests ({} vs {})",
        prep.request_tracks,
        frep.request_tracks
    );
    assert!(prep.request_tracks > 0, "head sampling at 0.25 should keep some requests");
}

/// Full-chaos × retry+hedge fleet: the merged fleet trace must still
/// lint clean (crash-severed lifecycles close as `lost`, retries reopen
/// fresh tracks), reconcile with the fleet's requests_total counters
/// (done includes voided hedge duplicates on both sides), carry
/// scheduler decision records, and render an attribution report. The
/// per-replica request log rides along tagged by replica id.
#[test]
fn chaos_guardrail_fleet_trace_lints_and_reconciles() {
    use econoserve::fleet::{self, FleetConfig};
    use econoserve::trace::{TraceGen, TraceSpec};
    let mut cfg = mini_cfg(4096);
    cfg.seed = 37;
    let gen = TraceGen::new(TraceSpec::sharegpt());
    let items = gen.generate(400, 2.0, 1024, 37);
    let mut fc = FleetConfig::new(cfg.clone(), "econoserve", "sharegpt");
    fc.oracle = true;
    fc.router = "least-kvc".to_string();
    fc.autoscaler = "reactive".to_string();
    fc.init_replicas = 2;
    fc.min_replicas = 2;
    fc.max_replicas = 4;
    fc.boot_latency = 5.0;
    fc.max_sim_time = 2_000.0;
    fc.faults = "full-chaos".to_string();
    fc.guardrails = "retry+hedge".to_string();
    fc.tracing = Some(TraceConfig::new(derive_seed(cfg.seed, stream::TRACE)));
    fc.reqlog_capacity = 256;
    let res = fleet::run(&fc, &items);

    let doc = res.trace_doc.as_ref().expect("fleet tracing was enabled");
    let text = doc.to_chrome_string();
    let rep = lint(&text).unwrap_or_else(|e| panic!("chaos fleet trace lint failed: {e}"));
    assert!(rep.request_tracks > 0, "no request tracks in the fleet trace");
    reconcile(&rep, &res.metrics)
        .unwrap_or_else(|e| panic!("fleet trace/metrics reconcile failed: {e}"));

    let skip_total: u64 = doc.skips.values().flat_map(|c| c.iter()).sum();
    assert!(skip_total > 0, "chaos fleet recorded no scheduler decision records");

    let table = report(&text).expect("trace-report renders");
    assert!(table.contains("TOTAL"), "attribution table missing TOTAL row");

    let log = res.reqlog.as_ref().expect("reqlog was enabled");
    assert!(!log.is_empty(), "request log is empty");
    assert!(
        log.lines().all(|l| l.starts_with("{\"replica\":")),
        "every reqlog line must be tagged with its replica id"
    );
}
