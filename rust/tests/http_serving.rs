//! End-to-end HTTP serving test: boot the std-only HTTP front-end on the
//! real PJRT model, issue concurrent generate requests, check stats.
//! Requires `make artifacts` (skips loudly otherwise).

use econoserve::server::http::{http_request, HttpServer};

fn artifacts() -> Option<String> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir.to_string_lossy().into_owned())
    } else {
        eprintln!("SKIP http_serving: run `make artifacts` first");
        None
    }
}

#[test]
fn generate_and_stats_roundtrip() {
    let Some(dir) = artifacts() else { return };
    let server = HttpServer::start("127.0.0.1:0", &dir).expect("start server");
    let addr = server.addr;

    // Health check.
    let (code, body) = http_request(&addr, "GET", "/health", "").unwrap();
    assert_eq!(code, 200);
    assert!(body.contains("ok"));

    // Three concurrent generate requests (exercises slot batching).
    let mut handles = Vec::new();
    for i in 0..3 {
        handles.push(std::thread::spawn(move || {
            let req = format!(
                r#"{{"prompt": [{}, {}, {}], "max_new_tokens": 6}}"#,
                10 + i,
                20 + i,
                30 + i
            );
            http_request(&addr, "POST", "/v1/generate", &req).unwrap()
        }));
    }
    for h in handles {
        let (code, body) = h.join().unwrap();
        assert_eq!(code, 200, "{body}");
        assert!(body.contains("\"tokens\""), "{body}");
        assert!(body.contains("\"latency_s\""), "{body}");
    }

    // Stats reflect the completions.
    let (code, body) = http_request(&addr, "GET", "/v1/stats", "").unwrap();
    assert_eq!(code, 200);
    assert!(body.contains("\"completed\":3"), "{body}");

    // Bad requests are rejected, not crashed.
    let (code, _) = http_request(&addr, "POST", "/v1/generate", "{}").unwrap();
    assert_eq!(code, 400);
    let (code, _) = http_request(&addr, "GET", "/nope", "").unwrap();
    assert_eq!(code, 404);

    server.shutdown();
}
