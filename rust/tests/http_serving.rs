//! End-to-end HTTP serving tests: boot the std-only HTTP front-end on the
//! real PJRT model and exercise the unified request-lifecycle API —
//! blocking generation, per-token streaming, structured 4xx errors,
//! admission-control shedding (429), disconnect-as-cancellation, the
//! Prometheus `/metrics` scrape, per-key token-bucket rate limiting, the
//! OpenAI-compatible facade, and graceful drain on shutdown.
//! Requires `make artifacts` (skips loudly otherwise).

use econoserve::api::{AdmissionConfig, RateLimitConfig};
use econoserve::ordering::QueuePolicy;
use econoserve::server::http::{http_request, http_request_with_key, ChunkStream, HttpServer};
use econoserve::server::ServerConfig;
use econoserve::telemetry::Snapshot;
use econoserve::util::json::Json;

fn artifacts() -> Option<String> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir.to_string_lossy().into_owned())
    } else {
        eprintln!("SKIP http_serving: run `make artifacts` first");
        None
    }
}

#[test]
fn generate_and_stats_roundtrip() {
    let Some(dir) = artifacts() else { return };
    let server = HttpServer::start("127.0.0.1:0", &dir).expect("start server");
    let addr = server.addr;

    // Health check.
    let (code, body) = http_request(&addr, "GET", "/health", "").unwrap();
    assert_eq!(code, 200);
    assert!(body.contains("ok"));

    // Three concurrent generate requests (exercises slot batching).
    let mut handles = Vec::new();
    for i in 0..3 {
        handles.push(std::thread::spawn(move || {
            let req = format!(
                r#"{{"prompt": [{}, {}, {}], "max_new_tokens": 6, "slo_budget_s": 300}}"#,
                10 + i,
                20 + i,
                30 + i
            );
            http_request(&addr, "POST", "/v1/generate", &req).unwrap()
        }));
    }
    for h in handles {
        let (code, body) = h.join().unwrap();
        assert_eq!(code, 200, "{body}");
        assert!(body.contains("\"tokens\""), "{body}");
        assert!(body.contains("\"latency_s\""), "{body}");
        assert!(body.contains("\"finish\":\"complete\""), "{body}");
        // A 300 s budget on a 6-token request must be met.
        assert!(body.contains("\"met_slo\":true"), "{body}");
    }

    // Stats reflect the completions.
    let (code, body) = http_request(&addr, "GET", "/v1/stats", "").unwrap();
    assert_eq!(code, 200);
    assert!(body.contains("\"completed\":3"), "{body}");

    // Model info endpoint.
    let (code, body) = http_request(&addr, "GET", "/v1/info", "").unwrap();
    assert_eq!(code, 200);
    let info = Json::parse(&body).unwrap();
    assert!(info.get("decode_slots").and_then(|v| v.as_usize()).unwrap() >= 1);
    assert!(info.get("max_prompt").and_then(|v| v.as_usize()).unwrap() >= 1);

    server.shutdown();
}

#[test]
fn http_error_paths_are_structured_4xx() {
    let Some(dir) = artifacts() else { return };
    let server = HttpServer::start("127.0.0.1:0", &dir).expect("start server");
    let addr = server.addr;

    let max_prompt = {
        let (_, body) = http_request(&addr, "GET", "/v1/info", "").unwrap();
        Json::parse(&body).unwrap().get("max_prompt").and_then(|v| v.as_usize()).unwrap()
    };

    // Malformed JSON body.
    let (code, body) = http_request(&addr, "POST", "/v1/generate", "{not json").unwrap();
    assert_eq!(code, 400, "{body}");
    assert!(body.contains("\"kind\":\"invalid_request\""), "{body}");

    // Missing prompt field.
    let (code, body) = http_request(&addr, "POST", "/v1/generate", "{}").unwrap();
    assert_eq!(code, 400, "{body}");
    assert!(body.contains("\"kind\":\"invalid_request\""), "{body}");

    // Empty prompt.
    let (code, body) =
        http_request(&addr, "POST", "/v1/generate", r#"{"prompt": []}"#).unwrap();
    assert_eq!(code, 400, "{body}");
    assert!(body.contains("\"kind\":\"invalid_request\""), "{body}");

    // Prompt over the prefill window.
    let long: Vec<String> = (0..max_prompt + 1).map(|_| "3".to_string()).collect();
    let req = format!(r#"{{"prompt": [{}], "max_new_tokens": 2}}"#, long.join(","));
    let (code, body) = http_request(&addr, "POST", "/v1/generate", &req).unwrap();
    assert_eq!(code, 400, "{body}");
    assert!(body.contains("\"kind\":\"prompt_too_long\""), "{body}");

    // Unknown route.
    let (code, body) = http_request(&addr, "GET", "/nope", "").unwrap();
    assert_eq!(code, 404, "{body}");
    assert!(body.contains("\"kind\":\"not_found\""), "{body}");

    // The same errors on the streaming endpoint (rejected before any
    // chunked output starts).
    let (code, body) = http_request(&addr, "POST", "/v1/stream", "{}").unwrap();
    assert_eq!(code, 400, "{body}");
    assert!(body.contains("\"kind\":\"invalid_request\""), "{body}");

    server.shutdown();
}

#[test]
fn stream_delivers_tokens_incrementally() {
    let Some(dir) = artifacts() else { return };
    let server = HttpServer::start("127.0.0.1:0", &dir).expect("start server");
    let addr = server.addr;

    let mut stream = ChunkStream::open(
        &addr,
        "/v1/stream",
        r#"{"prompt": [5, 6, 7], "max_new_tokens": 6}"#,
    )
    .expect("open stream");
    assert_eq!(stream.status, 200);
    let chunks = stream.collect_remaining();
    let token_chunks: Vec<&String> =
        chunks.iter().filter(|c| c.contains("\"token\"")).collect();
    let done_pos = chunks.iter().position(|c| c.contains("\"done\":true"));
    assert!(
        token_chunks.len() >= 2,
        "expected >=2 incremental token chunks before completion, got {chunks:?}"
    );
    assert_eq!(
        done_pos,
        Some(chunks.len() - 1),
        "terminal chunk must close the stream: {chunks:?}"
    );
    // Token indices arrive in order from 0.
    let first = Json::parse(token_chunks[0].trim()).unwrap();
    assert_eq!(first.get("index").and_then(|v| v.as_usize()), Some(0));
    // The terminal chunk is a full completion record.
    let done = Json::parse(chunks.last().unwrap().trim()).unwrap();
    assert_eq!(done.get("finish").and_then(|v| v.as_str()), Some("complete"));
    assert_eq!(done.get("tokens").and_then(|v| v.as_arr()).map(|a| a.len()), Some(6));

    server.shutdown();
}

#[test]
fn dropping_stream_connection_cancels_request() {
    let Some(dir) = artifacts() else { return };
    let server = HttpServer::start("127.0.0.1:0", &dir).expect("start server");
    let addr = server.addr;

    // A long request that cannot finish quickly.
    let mut stream = ChunkStream::open(
        &addr,
        "/v1/stream",
        r#"{"prompt": [9, 8, 7], "max_new_tokens": 100000}"#,
    )
    .expect("open stream");
    assert_eq!(stream.status, 200);
    assert!(stream.next_chunk().is_some(), "first token arrives");
    assert!(stream.next_chunk().is_some(), "second token arrives");
    drop(stream); // disconnect mid-generation

    // The server notices on its next chunk write, cancels, and frees the
    // slot; the cancellation becomes visible in /v1/stats.
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
    loop {
        let (code, body) = http_request(&addr, "GET", "/v1/stats", "").unwrap();
        assert_eq!(code, 200);
        let cancelled = Json::parse(&body)
            .unwrap()
            .get("cancelled")
            .and_then(|v| v.as_usize())
            .unwrap_or(0);
        if cancelled >= 1 {
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "server never registered the disconnect as a cancellation: {body}"
        );
        std::thread::sleep(std::time::Duration::from_millis(50));
    }

    server.shutdown();
}

#[test]
fn admission_sheds_load_with_429() {
    let Some(dir) = artifacts() else { return };
    let cfg = ServerConfig {
        ordering: QueuePolicy::EconoServe,
        admission: AdmissionConfig { max_inflight: 2, ..Default::default() },
        ..Default::default()
    };
    let server = HttpServer::start_with("127.0.0.1:0", &dir, cfg).expect("start server");
    let addr = server.addr;

    // 4 long concurrent requests against a 2-request in-flight bound: the
    // overflow must be shed with a structured 429, not queued.
    let mut handles = Vec::new();
    for i in 0..4 {
        handles.push(std::thread::spawn(move || {
            let req = format!(
                r#"{{"prompt": [{}, {}], "max_new_tokens": 48, "slo_budget_s": 300}}"#,
                20 + i,
                30 + i
            );
            http_request(&addr, "POST", "/v1/generate", &req).unwrap()
        }));
    }
    let mut ok = 0usize;
    let mut shed = 0usize;
    for h in handles {
        let (code, body) = h.join().unwrap();
        match code {
            200 => {
                ok += 1;
                // Accepted requests still carry correct SLO accounting.
                assert!(body.contains("\"met_slo\":true"), "{body}");
                assert!(body.contains("\"finish\":\"complete\""), "{body}");
            }
            429 => {
                shed += 1;
                assert!(body.contains("\"kind\":\"queue_full\""), "{body}");
            }
            other => panic!("unexpected status {other}: {body}"),
        }
    }
    assert!(shed >= 1, "overfilling a 2-deep bound must shed load");
    assert_eq!(ok + shed, 4);

    // The shed count is recorded in stats.
    let (_, body) = http_request(&addr, "GET", "/v1/stats", "").unwrap();
    let stats = Json::parse(&body).unwrap();
    assert_eq!(stats.get("rejected").and_then(|v| v.as_usize()), Some(shed));
    assert_eq!(stats.get("completed").and_then(|v| v.as_usize()), Some(ok));

    server.shutdown();
}

#[test]
fn metrics_scrape_is_parseable_and_reconciles_with_stats() {
    let Some(dir) = artifacts() else { return };
    let server = HttpServer::start("127.0.0.1:0", &dir).expect("start server");
    let addr = server.addr;

    for i in 0..2 {
        let req = format!(r#"{{"prompt": [{}, {}], "max_new_tokens": 3}}"#, 10 + i, 20 + i);
        let (code, body) = http_request(&addr, "POST", "/v1/generate", &req).unwrap();
        assert_eq!(code, 200, "{body}");
    }

    // The scrape is strict exposition text: the registry's own parser
    // must accept it, and its counters must agree with /v1/stats.
    let (code, text) = http_request(&addr, "GET", "/metrics", "").unwrap();
    assert_eq!(code, 200);
    let snap = Snapshot::parse(&text).expect("scrape parses as exposition text");
    assert_eq!(
        snap.value("econoserve_requests_total", &[("outcome", "done")]),
        Some(2.0),
        "{text}"
    );
    assert_eq!(snap.value("econoserve_iterations_total", &[]).map(|v| v > 0.0), Some(true));
    // HTTP-layer metrics cover the generate calls (route label is
    // normalized, so arbitrary paths cannot mint label cardinality).
    assert_eq!(
        snap.value(
            "econoserve_http_requests_total",
            &[("route", "/v1/generate"), ("status", "200")]
        ),
        Some(2.0),
        "{text}"
    );

    server.shutdown();
}

#[test]
fn rate_limiter_sheds_per_key_with_429() {
    let Some(dir) = artifacts() else { return };
    let cfg = ServerConfig {
        // Burst of 2, effectively no refill within the test's lifetime.
        rate_limit: RateLimitConfig::per_key(0.001, 2.0),
        ..Default::default()
    };
    let server = HttpServer::start_with("127.0.0.1:0", &dir, cfg).expect("start server");
    let addr = server.addr;

    let req = r#"{"prompt": [4, 5], "max_new_tokens": 2}"#;
    // The anonymous key exhausts its burst of 2, then gets a structured
    // 429 distinct from admission's queue_full.
    for _ in 0..2 {
        let (code, body) = http_request(&addr, "POST", "/v1/generate", req).unwrap();
        assert_eq!(code, 200, "{body}");
    }
    let (code, body) = http_request(&addr, "POST", "/v1/generate", req).unwrap();
    assert_eq!(code, 429, "{body}");
    assert!(body.contains("\"kind\":\"rate_limited\""), "{body}");

    // Keys are isolated: a different x-api-key has its own bucket.
    let (code, body) =
        http_request_with_key(&addr, "POST", "/v1/generate", req, Some("alice")).unwrap();
    assert_eq!(code, 200, "{body}");

    // Reads stay unthrottled, and the shed shows up in telemetry (not in
    // the engine's rejected count — the request never reached admission).
    let (code, text) = http_request(&addr, "GET", "/metrics", "").unwrap();
    assert_eq!(code, 200);
    let snap = Snapshot::parse(&text).expect("scrape parses");
    assert_eq!(snap.value("econoserve_rate_limited_total", &[]), Some(1.0), "{text}");
    let (_, body) = http_request(&addr, "GET", "/v1/stats", "").unwrap();
    assert_eq!(
        Json::parse(&body).unwrap().get("rejected").and_then(|v| v.as_usize()),
        Some(0)
    );

    server.shutdown();
}

#[test]
fn openai_facade_completions_and_models() {
    let Some(dir) = artifacts() else { return };
    let server = HttpServer::start("127.0.0.1:0", &dir).expect("start server");
    let addr = server.addr;

    // Model listing.
    let (code, body) = http_request(&addr, "GET", "/v1/models", "").unwrap();
    assert_eq!(code, 200);
    let models = Json::parse(&body).unwrap();
    assert_eq!(models.get("object").and_then(|v| v.as_str()), Some("list"));
    assert!(body.contains("econoserve-pjrt"), "{body}");

    // Blocking completion with a string prompt (bytes-as-token-ids).
    let (code, body) = http_request(
        &addr,
        "POST",
        "/v1/completions",
        r#"{"prompt": "hi", "max_tokens": 4}"#,
    )
    .unwrap();
    assert_eq!(code, 200, "{body}");
    let c = Json::parse(&body).unwrap();
    assert_eq!(c.get("object").and_then(|v| v.as_str()), Some("text_completion"));
    let finish = c
        .get("choices")
        .and_then(|v| v.as_arr())
        .and_then(|a| a.first())
        .and_then(|ch| ch.get("finish_reason"))
        .and_then(|v| v.as_str())
        .map(|s| s.to_string());
    assert!(
        finish.as_deref() == Some("stop") || finish.as_deref() == Some("length"),
        "{body}"
    );
    let used = c
        .get("usage")
        .and_then(|v| v.get("completion_tokens"))
        .and_then(|v| v.as_usize())
        .unwrap();
    assert!(used >= 1 && used <= 4, "{body}");

    // Streaming completion: SSE frames ending with data: [DONE].
    let mut stream = ChunkStream::open(
        &addr,
        "/v1/completions",
        r#"{"prompt": [7, 8], "max_tokens": 3, "stream": true}"#,
    )
    .expect("open sse stream");
    assert_eq!(stream.status, 200);
    let frames = stream.collect_remaining();
    assert!(frames.len() >= 2, "{frames:?}");
    assert!(
        frames.iter().all(|f| f.starts_with("data: ")),
        "every frame is an SSE data line: {frames:?}"
    );
    assert!(frames.last().unwrap().contains("[DONE]"), "{frames:?}");
    assert!(
        frames[frames.len() - 2].contains("finish_reason"),
        "penultimate frame carries the finish reason: {frames:?}"
    );

    server.shutdown();
}

#[test]
fn graceful_shutdown_drains_streams_and_refuses_new_connections() {
    let Some(dir) = artifacts() else { return };
    let server = HttpServer::start("127.0.0.1:0", &dir).expect("start server");
    let addr = server.addr;

    // An effectively unbounded stream keeps one connection in flight for
    // the whole drain window.
    let mut stream = ChunkStream::open(
        &addr,
        "/v1/stream",
        r#"{"prompt": [3, 4, 5], "max_new_tokens": 100000}"#,
    )
    .expect("open stream");
    assert_eq!(stream.status, 200);
    assert!(stream.next_chunk().is_some(), "stream is live before shutdown");

    let drainer = std::thread::spawn(move || {
        server.shutdown_within(std::time::Duration::from_secs(30));
    });

    // Once the drain begins, new connections get a structured 503 while
    // the in-flight stream keeps delivering tokens.
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
    loop {
        let (code, body) = http_request(&addr, "GET", "/health", "").unwrap();
        if code == 503 {
            assert!(body.contains("\"kind\":\"shutting_down\""), "{body}");
            break;
        }
        assert_eq!(code, 200, "{body}");
        assert!(
            std::time::Instant::now() < deadline,
            "shutdown never started refusing new connections"
        );
        std::thread::sleep(std::time::Duration::from_millis(20));
    }
    assert!(
        stream.next_chunk().is_some(),
        "in-flight stream still delivers during the drain"
    );

    // Dropping the last in-flight connection lets the drain finish; the
    // engine cancels the orphaned request and shuts down cleanly.
    drop(stream);
    drainer.join().expect("graceful shutdown completes");
}
