//! Cross-module integration tests: every system × every trace runs to
//! completion on the calibrated engine, plus the Table 1 capability
//! matrix assertions.

use econoserve::cluster::{DistServeConfig, DistServeSim};
use econoserve::figures::common;
use econoserve::kvc::Allocator;
use econoserve::trace::{TraceGen, TraceSpec};

fn slice(trace: &str, n: usize, rate_frac: f64, seed: u64) -> (econoserve::config::SystemConfig, Vec<econoserve::trace::TraceItem>) {
    let cfg = common::cfg("opt-13b", trace);
    let rate = common::capacity_estimate(&cfg, trace) * rate_frac;
    let gen = TraceGen::new(TraceSpec::by_name(trace).unwrap());
    let items = gen.generate(n, rate, cfg.profile.max_total_len, seed);
    (cfg, items)
}

#[test]
fn all_systems_complete_all_traces() {
    for trace in common::traces() {
        let (cfg, items) = slice(trace, 60, 0.7, 3);
        for sys in econoserve::sched::all_systems() {
            let (res, world) = common::run_world(&cfg, sys, trace, &items, false, 3600.0);
            assert_eq!(res.summary.n_done, items.len(), "{sys} on {trace}");
            assert_eq!(world.kvc().total_allocated(), 0, "{sys} on {trace} leaked KVC");
        }
    }
}

#[test]
fn sched_alloc_grid_runs_end_to_end() {
    // The registry's two-axis grammar: pinned allocators run the same
    // schedulers end-to-end (the ISSUE-2 acceptance combos).
    let (cfg, items) = slice("sharegpt", 50, 0.7, 21);
    for combo in ["vllm+exact", "sarathi+pipelined-exact", "econoserve+exact", "orca+pipelined-max"]
    {
        let (res, world) = common::run_world(&cfg, combo, "sharegpt", &items, false, 3600.0);
        assert_eq!(res.summary.n_done, items.len(), "{combo}");
        assert_eq!(world.kvc().total_allocated(), 0, "{combo} leaked KVC");
        world.kvc().check_invariants();
    }
}

#[test]
fn vllm_exact_grid_point_avoids_midflight_failures() {
    // Table 1 recomposed: vLLM's batching with exact-allocation leases
    // stops failing mid-flight under the same pressure that makes
    // vllm+block thrash (admission head-of-line blocks instead).
    let (cfg, items) = pressure();
    let (res, world) = common::run_world(&cfg, "vllm+exact", "sharegpt", &items, true, 3600.0);
    assert_eq!(res.summary.n_done, items.len());
    assert_eq!(res.summary.alloc_failure_frac, 0.0, "no in-flight failures under exact");
    assert_eq!(world.col.swap_preemptions, 0);
}

#[test]
fn distserve_completes_all_traces() {
    for trace in common::traces() {
        let (cfg, items) = slice(trace, 60, 0.7, 5);
        let dcfg = DistServeConfig::homogeneous(cfg.profile.clone(), &cfg);
        let res = DistServeSim::new(dcfg).run(&items, 3600.0);
        assert_eq!(res.summary.n_done, items.len(), "distserve on {trace}");
    }
}

// ----------------------------------------------------------------------
// Table 1 capability matrix, asserted behaviourally.
// ----------------------------------------------------------------------

/// Pressure scenario: KVC-bound ShareGPT slice.
fn pressure() -> (econoserve::config::SystemConfig, Vec<econoserve::trace::TraceItem>) {
    let mut cfg = common::cfg("opt-13b", "sharegpt");
    cfg.profile.kvc_bytes = 819_200 * 4096; // 4k tokens: tight
    let gen = TraceGen::new(TraceSpec::sharegpt());
    let items = gen.generate(60, 1.2, cfg.profile.max_total_len, 9);
    (cfg, items)
}

#[test]
fn tab1_orca_avoids_alloc_failures_via_max_allocation() {
    let (mut cfg, items) = pressure();
    cfg.profile.max_total_len = 2048;
    let (res, world) = common::run_world(&cfg, "orca", "sharegpt", &items, true, 3600.0);
    // Admission attempts may bounce (head-of-line), but no admitted request
    // ever hits an in-flight allocation failure (the Fig 1d metric).
    assert_eq!(res.summary.alloc_failure_frac, 0.0);
    let _ = world;
}

#[test]
fn tab1_vllm_hits_alloc_failures_under_pressure() {
    let (cfg, items) = pressure();
    let (res, world) = common::run_world(&cfg, "vllm", "sharegpt", &items, true, 3600.0);
    assert!(world.kvc().stats().failures > 0, "block-allocation must fail under pressure");
    assert!(res.summary.alloc_failure_frac > 0.0);
    assert!(world.col.alloc_exhausted > 0, "typed outcomes must record the exhaustion");
}

#[test]
fn tab1_econoserve_avoids_alloc_failures() {
    let (cfg, items) = pressure();
    let (res, world) = common::run_world(&cfg, "econoserve", "sharegpt", &items, true, 3600.0);
    // Exact allocation: no mid-flight failures (admission rejections are
    // not failures; the paper's Fig 1d counts in-execution failures).
    assert_eq!(res.summary.n_done, items.len());
    let _ = world;
}

#[test]
fn tab1_sarathi_mixes_prefill_and_decode() {
    // "Increase GPU uti. when KVC allows": Sarathi reaches bigger forward
    // sizes than vLLM by chunking prompts into decode iterations.
    let (cfg, items) = slice("bookcorpus", 40, 0.8, 11);
    let (sarathi, _) = common::run_world(&cfg, "sarathi", "bookcorpus", &items.clone(), true, 3600.0);
    let (orca, _) = common::run_world(&cfg, "orca", "bookcorpus", &items, true, 3600.0);
    assert!(
        sarathi.summary.avg_forward_size > orca.summary.avg_forward_size,
        "sarathi fwd {} vs orca {}",
        sarathi.summary.avg_forward_size,
        orca.summary.avg_forward_size
    );
}

#[test]
fn tab1_econoserve_outperforms_coupled_baselines() {
    // The paper's core comparison: EconoServe vs ORCA-family baselines.
    let (cfg, items) = slice("sharegpt", 80, 0.9, 13);
    let (econo, _) = common::run_world(&cfg, "econoserve", "sharegpt", &items.clone(), false, 3600.0);
    let (orca, _) = common::run_world(&cfg, "orca", "sharegpt", &items.clone(), false, 3600.0);
    let (srtf, _) = common::run_world(&cfg, "srtf", "sharegpt", &items, false, 3600.0);
    assert!(
        econo.summary.mean_jct < orca.summary.mean_jct * 0.5,
        "econoserve {} vs orca {}",
        econo.summary.mean_jct,
        orca.summary.mean_jct
    );
    assert!(econo.summary.mean_jct < srtf.summary.mean_jct);
}

#[test]
fn slo_ordering_raises_ssr() {
    // Ordering's purpose (§3.4): higher SSR than the unordered variant at
    // the same load.
    let (cfg, items) = slice("sharegpt", 120, 1.0, 17);
    let (sdo, _) = common::run_world(&cfg, "econoserve-sdo", "sharegpt", &items.clone(), false, 3600.0);
    let (sd, _) = common::run_world(&cfg, "econoserve-sd", "sharegpt", &items, false, 3600.0);
    assert!(
        sdo.summary.ssr >= sd.summary.ssr * 0.95,
        "ordering should not hurt SSR: sdo {} sd {}",
        sdo.summary.ssr,
        sd.summary.ssr
    );
}

#[test]
fn trace_stats_match_table2() {
    for spec in TraceSpec::all() {
        let gen = TraceGen::new(spec);
        let items = gen.generate(20_000, spec.default_rate, 1 << 20, 7);
        let s = econoserve::trace::stats(&items);
        assert!((s.in_avg - spec.input.avg).abs() / spec.input.avg < 0.12, "{}", spec.name);
        assert!((s.out_avg - spec.output.avg).abs() / spec.output.avg < 0.12, "{}", spec.name);
    }
}
