//! Equivalence properties for the indexed hot path: the incremental
//! structures (BucketQueue, the world's active index, the per-scheduler
//! indexed queues) must produce the SAME decisions as the plain
//! linear-scan formulations they replaced — on randomized, seeded inputs,
//! for every supported sched+alloc registry combo.
//!
//! Plus the parallel experiment engine's determinism contract: grid
//! sweeps, figure rows, and fleet runs must be **bit-identical** at any
//! worker-thread count (1 vs 4 pinned here) — parallelism is a
//! wall-clock knob, never a results knob.

use std::collections::hash_map::DefaultHasher;
use std::hash::{Hash, Hasher};

use econoserve::config::{ModelProfile, SystemConfig};
use econoserve::core::world::World;
use econoserve::engine::{Engine, SimEngine};
use econoserve::ordering::{BucketQueue, OrderKey, QueuePolicy, QueuedTask};
use econoserve::predictor::SimPredictor;
use econoserve::sched::plan_iteration;
use econoserve::trace::TraceItem;
use econoserve::util::prop::{run_prop, sized};
use econoserve::util::rng::Rng;

// ---------------------------------------------------------------------
// BucketQueue vs. linear min-scan reference
// ---------------------------------------------------------------------

#[derive(Clone, Copy, Debug)]
struct RefEntry {
    id: usize,
    priority: u8,
    deadline: f64,
    occupied: u32,
    len: u32,
}

fn ref_key(policy: QueuePolicy, e: &RefEntry, clock: f64) -> OrderKey {
    policy.key(&QueuedTask {
        seq: e.id as u64,
        priority: e.priority,
        slack: e.deadline - clock,
        occupied_kvc: e.occupied,
        len: e.len,
    })
}

/// The linear-scan selection the bucket queue replaces: min canonical
/// key over the whole queue at the current clock.
fn ref_min(policy: QueuePolicy, model: &[RefEntry], clock: f64) -> Option<usize> {
    model.iter().min_by_key(|e| ref_key(policy, e, clock)).map(|e| e.id)
}

/// Reference best-fit: min canonical key among entries with len <= cap
/// (see the walk in `BucketQueue::best_fit_leq` — group order dominates,
/// so this is exactly the first fitting bucket's longest member).
fn ref_best_fit(policy: QueuePolicy, model: &[RefEntry], cap: u32, clock: f64) -> Option<usize> {
    model
        .iter()
        .filter(|e| e.len <= cap)
        .min_by_key(|e| ref_key(policy, e, clock))
        .map(|e| e.id)
}

#[test]
fn bucket_queue_matches_linear_scan_reference() {
    run_prop("bucket_queue_equivalence", 250, |rng| {
        let policy = if rng.chance(0.8) { QueuePolicy::EconoServe } else { QueuePolicy::Fcfs };
        let mut q = BucketQueue::new(policy);
        let mut model: Vec<RefEntry> = Vec::new();
        let mut clock = 0.0f64;
        let mut next_id = 0usize;
        for _ in 0..sized(rng, 150) {
            // The clock only moves forward (slack only shrinks), exactly
            // like the simulation.
            if rng.chance(0.5) {
                clock += rng.exponential(2.0);
            }
            match rng.range_u64(0, 5) {
                0 | 1 => {
                    let e = RefEntry {
                        id: next_id,
                        priority: rng.range_u64(0, 2) as u8,
                        // deadlines around the bucket edges (0.5 s / 2 s
                        // of slack) to stress migrations
                        deadline: clock + rng.f64() * 4.0,
                        occupied: (rng.range_u64(0, 6) * 200) as u32,
                        len: 1 + rng.range_u64(0, 600) as u32,
                    };
                    next_id += 1;
                    model.push(e);
                    q.push(e.id, e.priority, e.deadline, e.occupied, e.len, clock);
                }
                2 => {
                    if model.is_empty() {
                        continue;
                    }
                    let idx = rng.range_usize(0, model.len() - 1);
                    let victim = model.swap_remove(idx);
                    assert!(q.remove(victim.id), "queued entry must be removable");
                }
                3 => {
                    // Event-driven re-bucketing: occupancy/length change.
                    if model.is_empty() {
                        continue;
                    }
                    let idx = rng.range_usize(0, model.len() - 1);
                    model[idx].occupied = (rng.range_u64(0, 6) * 200) as u32;
                    model[idx].len = 1 + rng.range_u64(0, 600) as u32;
                    let e = model[idx];
                    q.update(e.id, e.occupied, e.len, clock);
                }
                4 => {
                    let want = ref_min(policy, &model, clock);
                    let got = q.pop_first(clock);
                    assert_eq!(got, want, "pop mismatch at clock {clock}");
                    if let Some(id) = got {
                        model.retain(|e| e.id != id);
                    }
                }
                _ => {
                    let cap = rng.range_u64(0, 700) as u32;
                    let want = ref_best_fit(policy, &model, cap, clock);
                    let got = q.best_fit_leq(cap, clock);
                    assert_eq!(got, want, "best_fit({cap}) mismatch at clock {clock}");
                }
            }
            assert_eq!(q.len(), model.len(), "length drift");
        }
        // Drain: the full pop order must equal repeated linear scans.
        while let Some(want) = ref_min(policy, &model, clock) {
            assert_eq!(q.pop_first(clock), Some(want), "drain order diverged");
            model.retain(|e| e.id != want);
            clock += rng.f64() * 0.3;
        }
        assert!(q.is_empty());
    });
}

// ---------------------------------------------------------------------
// World active index vs. whole-recs scan
// ---------------------------------------------------------------------

fn mini_cfg(kvc_tokens: u64) -> SystemConfig {
    let mut profile = ModelProfile::opt_13b();
    profile.kvc_bytes = 819_200 * kvc_tokens;
    profile.max_total_len = 1024;
    let mut cfg = SystemConfig::new(profile);
    cfg.t_p = 0.05;
    cfg.t_g = 0.022;
    cfg.sched_time_scale = 0.0;
    cfg
}

fn random_items(rng: &mut Rng, n: usize, max_len: u32) -> Vec<TraceItem> {
    let mut t = 0.0;
    (0..n)
        .map(|_| {
            t += rng.exponential(5.0);
            let prompt_len = 1 + sized(rng, (max_len / 3) as usize) as u32;
            let true_rl = 1 + sized(rng, (max_len - prompt_len).min(300) as usize) as u32;
            TraceItem { arrival: t, prompt_len, true_rl }
        })
        .collect()
}

#[test]
fn world_active_index_matches_whole_recs_scan() {
    run_prop("active_index_equivalence", 12, |rng| {
        let items = random_items(rng, 10 + sized(rng, 25), 800);
        let cfg = mini_cfg(4096);
        let pred = Box::new(SimPredictor::new(0.15, cfg.block_size, rng.next_u64()));
        let mut world = World::new(cfg, &items, pred);
        let sys = econoserve::sched::by_name("econoserve").unwrap();
        world.set_allocator(sys.alloc);
        let mut sched = sys.sched;
        let engine = SimEngine::new();
        for _ in 0..200_000u32 {
            world.drain_arrivals();
            // The O(1) index must agree with the linear-scan definitions
            // it replaced, at every iteration boundary.
            let scan_active = world
                .recs
                .iter()
                .filter(|r| r.req.arrival <= world.clock && !r.is_done())
                .count();
            assert_eq!(world.n_active(), scan_active, "active index drift");
            let scan_done = world.recs.iter().filter(|r| r.is_done()).count();
            assert_eq!(world.n_done(), scan_done, "done counter drift");
            assert_eq!(
                world.all_done(),
                world.recs.iter().all(|r| r.is_done()),
                "all_done drift"
            );
            let mut ids: Vec<usize> = world.active_ids().to_vec();
            ids.sort_unstable();
            ids.dedup();
            assert_eq!(ids.len(), world.n_active(), "active index holds duplicates");

            if world.all_done() {
                break;
            }
            let plan = plan_iteration(&mut world, sched.as_mut());
            if plan.is_empty() {
                match world.next_arrival() {
                    Some(t) if t > world.clock => world.clock = t,
                    _ => world.clock += 0.05,
                }
                continue;
            }
            let (d, u) = engine.iteration_cost(&plan, &world);
            world.apply_plan(&plan, d, u);
            world.recycle_plan(plan);
        }
        assert!(world.all_done(), "run did not complete");
    });
}

// ---------------------------------------------------------------------
// Whole-system determinism per registry combo (plan-stream identical)
// ---------------------------------------------------------------------

/// The supported sched×alloc grid (mirrors benches/sched_hotpath.rs).
fn supported_combos() -> Vec<String> {
    let mut combos = Vec::new();
    for (sched, allocs) in [
        ("orca", &["max", "pipelined-max"][..]),
        ("fastserve", &["max", "pipelined-max"][..]),
        ("vllm", &["block", "exact", "pipelined-block", "pipelined-exact"][..]),
        ("sarathi", &["block", "exact", "pipelined-block", "pipelined-exact"][..]),
        ("multires", &["exact", "pipelined-exact", "max"][..]),
        ("sync_coupled", &["exact", "pipelined-exact", "max"][..]),
        ("srtf", &["max", "pipelined-max"][..]),
        ("econoserve-d", &["exact"][..]),
        ("econoserve-sd", &["exact"][..]),
        ("econoserve-sdo", &["exact"][..]),
        ("econoserve", &["exact", "pipelined-exact", "max"][..]),
    ] {
        for a in allocs {
            combos.push(format!("{sched}+{a}"));
        }
    }
    combos
}

/// Drive a combo over `items` and return (n_done, iterations, plan-stream
/// hash). The hash covers every plan's tasks, preemptions and evictions —
/// two runs must agree bit-for-bit.
fn drive_hashed(combo: &str, items: &[TraceItem], seed: u64) -> (usize, u64, u64) {
    let cfg = mini_cfg(4096);
    let pred = Box::new(SimPredictor::new(0.15, cfg.block_size, seed));
    let mut world = World::new(cfg, items, pred);
    let sys = econoserve::sched::by_name(combo).unwrap_or_else(|| panic!("combo {combo}"));
    world.set_allocator(sys.alloc);
    let mut sched = sys.sched;
    let engine = SimEngine::new();
    let mut hasher = DefaultHasher::new();
    let mut iters = 0u64;
    for _ in 0..400_000u32 {
        if world.all_done() {
            break;
        }
        world.drain_arrivals();
        let plan = plan_iteration(&mut world, sched.as_mut());
        if plan.is_empty() {
            match world.next_arrival() {
                Some(t) if t > world.clock => world.clock = t,
                _ => world.clock += 0.05,
            }
            continue;
        }
        format!("{:?}|{:?}|{:?}", plan.tasks, plan.preempted, plan.evicted).hash(&mut hasher);
        let (d, u) = engine.iteration_cost(&plan, &world);
        world.apply_plan(&plan, d, u);
        world.recycle_plan(plan);
        iters += 1;
    }
    assert!(world.all_done(), "{combo}: run did not complete");
    (world.n_done(), iters, hasher.finish())
}

#[test]
fn every_combo_plan_stream_is_reproducible() {
    run_prop("combo_plan_determinism", 6, |rng| {
        let combos = supported_combos();
        let combo = &combos[rng.range_usize(0, combos.len() - 1)];
        let seed = rng.next_u64();
        let items = random_items(rng, 12 + sized(rng, 20), 700);
        let a = drive_hashed(combo, &items, seed);
        let b = drive_hashed(combo, &items, seed);
        assert_eq!(a, b, "{combo}: plan stream not reproducible (indexed structures leak nondeterminism)");
    });
}

#[test]
fn full_grid_smoke_identical_twice() {
    // Cheap full-grid pass (one small trace, every combo twice): catches
    // any combo whose indexed port lost determinism or completion.
    let mut rng = Rng::new(0xECC0);
    let items = random_items(&mut rng, 14, 600);
    for combo in supported_combos() {
        let a = drive_hashed(&combo, &items, 42);
        let b = drive_hashed(&combo, &items, 42);
        assert_eq!(a, b, "{combo} diverged across identical runs");
        assert_eq!(a.0, items.len(), "{combo} lost requests");
    }
}

// ---------------------------------------------------------------------
// Parallel experiment engine: thread count never changes results
// ---------------------------------------------------------------------

/// The whole movable-simulation contract in one place: everything the
/// parallel engine sends across worker threads must be `Send` (this is
/// what the `Send` supertraits on Scheduler/Allocator/Predictor/Router/
/// Autoscaler buy). Purely a compile-time pin.
#[test]
fn sim_core_is_send() {
    fn assert_send<T: Send>() {}
    assert_send::<World>();
    assert_send::<econoserve::coordinator::Stepper>();
    assert_send::<econoserve::sched::System>();
    assert_send::<Box<dyn econoserve::sched::Scheduler>>();
    assert_send::<Box<dyn econoserve::kvc::Allocator>>();
    assert_send::<Box<dyn econoserve::predictor::Predictor>>();
    assert_send::<Box<dyn econoserve::fleet::Router>>();
    assert_send::<Box<dyn econoserve::fleet::Autoscaler>>();
    assert_send::<econoserve::cluster::DistServeSim>();
}

/// `exp::map_indexed` ordering/determinism property: on randomized cell
/// counts and uneven per-cell work, results land in input order and
/// match the sequential map at every thread count.
#[test]
fn map_indexed_matches_sequential_reference() {
    use econoserve::util::rng::derive_seed;
    run_prop("map_indexed_determinism", 30, |rng| {
        let n = sized(rng, 120);
        let items: Vec<u64> = (0..n as u64).map(|i| derive_seed(rng.next_u64(), i)).collect();
        let work = |i: usize, x: &u64| {
            // Uneven cost so completion order scrambles under threads.
            let mut r = Rng::new(*x);
            let spins = r.range_u64(0, 500);
            let mut acc = *x;
            for _ in 0..spins {
                acc = acc.wrapping_add(r.next_u64());
            }
            (i, std::hint::black_box(acc))
        };
        let reference: Vec<(usize, u64)> =
            items.iter().enumerate().map(|(i, x)| work(i, x)).collect();
        for threads in [1usize, 4, 9] {
            let got = econoserve::exp::map_indexed(&items, threads, work);
            assert_eq!(got, reference, "threads={threads}");
        }
    });
}

/// A figure-scale rate × system grid produces bit-identical rows at 1
/// and 4 worker threads (sched_time_scale = 0 makes the sequential path
/// itself deterministic; the parallel path must not add anything).
#[test]
fn figure_grid_rows_bit_identical_across_thread_counts() {
    use econoserve::figures::common;
    let mut cfg = common::cfg("opt-13b", "alpaca");
    cfg.sched_time_scale = 0.0;
    let eval = |cfg: &econoserve::config::SystemConfig,
                sys: &'static str,
                items: &[TraceItem],
                _rate: f64| {
        let s = common::run_world(cfg, sys, "alpaca", items, true, 120.0).0.summary;
        (s.n_done, s.mean_jct.to_bits(), s.norm_latency.to_bits(), s.ssr.to_bits())
    };
    let rows1 = common::run_rate_grid(&cfg, "alpaca", 2, 5.0, &["orca", "vllm"], 1, eval);
    let rows4 = common::run_rate_grid(&cfg, "alpaca", 2, 5.0, &["orca", "vllm"], 4, eval);
    assert_eq!(rows1, rows4, "figure rows diverged across thread counts");
}

/// `exp::run_grid` (the `econoserve sweep` surface) emits bit-identical
/// JSON rows at 1 and 4 threads.
#[test]
fn sweep_rows_bit_identical_across_thread_counts() {
    use econoserve::exp::GridSpec;
    let mut spec = GridSpec {
        systems: vec!["orca".to_string(), "vllm".to_string()],
        models: vec!["opt-13b".to_string()],
        traces: vec!["alpaca".to_string()],
        rates: vec![2.0, 4.0],
        seeds: vec![1],
        duration: 5.0,
        max_time: 120.0,
        oracle: true,
        threads: 1,
        ..GridSpec::default()
    };
    let a = econoserve::exp::run_grid(&spec);
    spec.threads = 4;
    let b = econoserve::exp::run_grid(&spec);
    assert_eq!(a.rows, b.rows, "sweep rows diverged across thread counts");
    assert_eq!(a.rows.len(), 4, "2 systems x 2 rates");
}

/// Concurrent fleet stepping: the same run with serial (threads=1) and
/// parallel (threads=4) replica advancement yields the SAME
/// `FleetSummary` — replicas are data-independent between routing
/// events, so thread count is purely a wall-clock knob.
#[test]
fn fleet_summary_bit_identical_parallel_vs_sequential() {
    use econoserve::fleet::{self, FleetConfig};
    use econoserve::trace::{TraceGen, TraceSpec};
    let mut cfg = mini_cfg(4096);
    cfg.seed = 23;
    let gen = TraceGen::new(TraceSpec::sharegpt());
    let items = gen.generate(150, 8.0, 1024, 23);
    let run_with = |threads: usize| {
        let mut fc = FleetConfig::new(cfg.clone(), "econoserve", "sharegpt");
        fc.oracle = true;
        fc.router = "least-kvc".to_string();
        fc.autoscaler = "reactive".to_string();
        fc.init_replicas = 2;
        fc.min_replicas = 1;
        fc.max_replicas = 3;
        fc.boot_latency = 5.0;
        fc.max_sim_time = 600.0;
        fc.threads = threads;
        fleet::run(&fc, &items)
    };
    let serial = run_with(1);
    let parallel = run_with(4);
    assert_eq!(
        serial.summary, parallel.summary,
        "FleetSummary diverged between serial and parallel stepping"
    );
    assert_eq!(
        format!("{:?}", serial.replicas),
        format!("{:?}", parallel.replicas),
        "replica lifecycle logs diverged"
    );
    // The merged telemetry registry is part of the determinism contract:
    // byte-identical Prometheus text at any thread count.
    assert_eq!(
        serial.metrics, parallel.metrics,
        "telemetry snapshot diverged between serial and parallel stepping"
    );
    assert!(!serial.metrics.is_empty(), "fleet run must emit a telemetry snapshot");
}

// ---------------------------------------------------------------------
// Fault injection: chaos is deterministic too
// ---------------------------------------------------------------------

/// A fault profile compiles into a timeline that is a pure function of
/// (profile, seed): bit-identical on replay, different across seeds.
#[test]
fn fault_timelines_are_pure_functions_of_profile_and_seed() {
    use econoserve::fleet::faults;
    for name in econoserve::fleet::all_profiles() {
        let p = faults::by_name(name).unwrap();
        let a = faults::timeline(p, 0xC0FFEE, 1_000.0);
        let b = faults::timeline(p, 0xC0FFEE, 1_000.0);
        assert_eq!(a, b, "{name}: timeline not reproducible per seed");
        if !a.is_empty() {
            let c = faults::timeline(p, 0xBEEF, 1_000.0);
            assert_ne!(a, c, "{name}: timeline ignores the seed");
        }
    }
}

/// The chaos variant of the fleet determinism pin: under the heaviest
/// fault profile, serial (threads=1) and parallel (threads=4) replica
/// stepping still yield the SAME `FleetSummary` — fault timelines and
/// victim picks read only thread-invariant state.
#[test]
fn chaos_fleet_summary_bit_identical_parallel_vs_sequential() {
    use econoserve::fleet::{self, FleetConfig};
    use econoserve::trace::{TraceGen, TraceSpec};
    let mut cfg = mini_cfg(4096);
    cfg.seed = 31;
    let gen = TraceGen::new(TraceSpec::sharegpt());
    let items = gen.generate(400, 2.0, 1024, 31);
    let run_with = |threads: usize| {
        let mut fc = FleetConfig::new(cfg.clone(), "econoserve", "sharegpt");
        fc.oracle = true;
        fc.router = "power-of-two".to_string();
        fc.autoscaler = "reactive".to_string();
        fc.init_replicas = 2;
        fc.min_replicas = 2;
        fc.max_replicas = 4;
        fc.boot_latency = 5.0;
        fc.max_sim_time = 2_000.0;
        fc.faults = "full-chaos".to_string();
        fc.threads = threads;
        fleet::run(&fc, &items)
    };
    let serial = run_with(1);
    let parallel = run_with(4);
    assert!(
        !serial.summary.faults.is_zero(),
        "full-chaos run saw no faults — the pin is vacuous"
    );
    assert_eq!(
        serial.summary, parallel.summary,
        "chaos FleetSummary diverged between serial and parallel stepping"
    );
    assert_eq!(
        format!("{:?}", serial.replicas),
        format!("{:?}", parallel.replicas),
        "chaos replica lifecycle logs diverged"
    );
    assert_eq!(
        serial.metrics, parallel.metrics,
        "chaos telemetry snapshot diverged between serial and parallel stepping"
    );

    // Reconciliation: the merged registry must agree with the summary's
    // independent accounting — counters are not a parallel bookkeeping
    // system that can drift, they are the same events counted once.
    use econoserve::telemetry::Snapshot;
    let snap = Snapshot::parse(&serial.metrics).expect("fleet metrics parse");
    assert_eq!(
        snap.value("econoserve_requests_total", &[("outcome", "done")]),
        Some(serial.summary.n_done as f64),
        "requests_total{{outcome=done}} != summary.n_done"
    );
    assert_eq!(
        snap.value("econoserve_requests_lost_total", &[]),
        Some(serial.summary.faults.lost as f64),
        "requests_lost_total != faults.lost"
    );
    assert_eq!(
        snap.value("econoserve_faults_total", &[("kind", "crash")]),
        Some(serial.summary.faults.crashes as f64),
        "faults_total{{kind=crash}} != faults.crashes"
    );
}

/// The guardrail variant of the fleet determinism pin: retries (backoff
/// + jitter from the dedicated GUARDRAILS rng stream) and hedging (race
/// resolution, loser cancellation, duplicate voiding) under full chaos
/// must still be bit-identical between serial and parallel stepping —
/// every guardrail decision reads only thread-invariant state.
#[test]
fn guardrail_fleet_summary_bit_identical_parallel_vs_sequential() {
    use econoserve::fleet::{self, FleetConfig};
    use econoserve::trace::{TraceGen, TraceSpec};
    let mut cfg = mini_cfg(4096);
    cfg.seed = 37;
    let gen = TraceGen::new(TraceSpec::sharegpt());
    let items = gen.generate(400, 2.0, 1024, 37);
    let run_with = |threads: usize| {
        let mut fc = FleetConfig::new(cfg.clone(), "econoserve", "sharegpt");
        fc.oracle = true;
        fc.router = "least-kvc".to_string();
        fc.autoscaler = "reactive".to_string();
        fc.init_replicas = 2;
        fc.min_replicas = 2;
        fc.max_replicas = 4;
        fc.boot_latency = 5.0;
        fc.max_sim_time = 2_000.0;
        fc.faults = "full-chaos".to_string();
        fc.guardrails = "retry+hedge".to_string();
        fc.threads = threads;
        fleet::run(&fc, &items)
    };
    let serial = run_with(1);
    let parallel = run_with(4);
    assert!(
        serial.summary.faults.retried > 0,
        "no retries fired — the guardrail pin is vacuous"
    );
    assert_eq!(
        serial.summary, parallel.summary,
        "guardrail FleetSummary diverged between serial and parallel stepping"
    );
    assert_eq!(
        serial.metrics, parallel.metrics,
        "guardrail telemetry snapshot diverged between serial and parallel stepping"
    );

    // Duplicate-corrected reconciliation: a hedge race where both copies
    // completed bumped `requests_total{outcome=done}` twice, then the
    // loser's completion was voided out of the summary. The monotonic
    // counter therefore exceeds n_done by EXACTLY the duplicate count.
    use econoserve::telemetry::Snapshot;
    let snap = Snapshot::parse(&serial.metrics).expect("fleet metrics parse");
    let dup = snap
        .value("econoserve_hedges_total", &[("outcome", "duplicate")])
        .expect("hedges_total{duplicate} present");
    assert_eq!(
        snap.value("econoserve_requests_total", &[("outcome", "done")]),
        Some(serial.summary.n_done as f64 + dup),
        "requests_total{{outcome=done}} != n_done + hedge duplicates"
    );
    assert_eq!(
        snap.value("econoserve_retries_total", &[]),
        Some(serial.summary.faults.retried as f64),
        "retries_total != faults.retried"
    );
    assert_eq!(
        snap.value("econoserve_hedges_total", &[("outcome", "won")]),
        Some(serial.summary.faults.hedges_won as f64),
        "hedges_total{{outcome=won}} != faults.hedges_won"
    );
    // The generalized conservation identity, under chaos + guardrails.
    let s = &serial.summary;
    assert_eq!(s.n_total, s.n_done + s.faults.lost + s.faults.aborted);
}

/// The merged fleet span trace is a pure function of (config, seed):
/// the exported Chrome-format bytes must be identical at 1 and 4
/// worker threads (per-replica recorders are single-threaded and the
/// merge runs in replica-id order at finalize).
#[test]
fn fleet_trace_bytes_bit_identical_across_thread_counts() {
    use econoserve::fleet::{self, FleetConfig};
    use econoserve::telemetry::TraceConfig;
    use econoserve::trace::{TraceGen, TraceSpec};
    use econoserve::util::rng::{derive_seed, stream};
    let mut cfg = mini_cfg(4096);
    cfg.seed = 37;
    let gen = TraceGen::new(TraceSpec::sharegpt());
    let items = gen.generate(400, 2.0, 1024, 37);
    let run_with = |threads: usize| {
        let mut fc = FleetConfig::new(cfg.clone(), "econoserve", "sharegpt");
        fc.oracle = true;
        fc.router = "least-kvc".to_string();
        fc.autoscaler = "reactive".to_string();
        fc.init_replicas = 2;
        fc.min_replicas = 2;
        fc.max_replicas = 4;
        fc.boot_latency = 5.0;
        fc.max_sim_time = 2_000.0;
        fc.faults = "full-chaos".to_string();
        fc.guardrails = "retry+hedge".to_string();
        fc.tracing = Some(TraceConfig::new(derive_seed(cfg.seed, stream::TRACE)));
        fc.threads = threads;
        fleet::run(&fc, &items)
    };
    let serial = run_with(1);
    let parallel = run_with(4);
    let a = serial.trace_doc.expect("tracing enabled").to_chrome_string();
    let b = parallel.trace_doc.expect("tracing enabled").to_chrome_string();
    assert!(!a.is_empty(), "serial trace is empty");
    assert_eq!(a, b, "fleet trace bytes diverged between serial and parallel stepping");
}

// ---------------------------------------------------------------------
// Predictor faults + adaptive headroom: resilience is deterministic too
// ---------------------------------------------------------------------

/// A predictor fault profile compiles into a timeline that is a pure
/// function of (profile, seed) — the predictor-side mirror of the fleet
/// fault pin above.
#[test]
fn predictor_fault_timelines_are_pure_functions_of_profile_and_seed() {
    use econoserve::predictor::faults;
    for name in faults::all_profiles() {
        let p = faults::by_name(name).unwrap();
        let a = faults::timeline(&p, 0xC0FFEE, 1_000.0);
        let b = faults::timeline(&p, 0xC0FFEE, 1_000.0);
        assert_eq!(a, b, "{name}: timeline not reproducible per seed");
        if !a.is_empty() {
            let c = faults::timeline(&p, 0xBEEF, 1_000.0);
            assert_ne!(a, c, "{name}: timeline ignores the seed");
        }
    }
}

/// The prediction-fault variant of the fleet determinism pin: with
/// regime-shift predictor chaos AND the adaptive headroom controller
/// live, serial (threads=1) and parallel (threads=4) replica stepping
/// still yield the SAME summary, lifecycle log, and telemetry text —
/// fault timelines and every adaptive padding/eviction-budget decision
/// read only thread-invariant state. Plus the predictions_total
/// reconciliation: the merged registry's verdict counters must equal
/// the per-replica summaries' independent accounting.
#[test]
fn prediction_fault_fleet_bit_identical_and_counters_reconcile() {
    use econoserve::fleet::{self, FleetConfig};
    use econoserve::trace::{TraceGen, TraceSpec};
    let mut cfg = mini_cfg(4096);
    cfg.seed = 41;
    cfg.predictor_faults = "regime-shift".to_string();
    cfg.headroom = "adaptive".to_string();
    let gen = TraceGen::new(TraceSpec::sharegpt());
    let items = gen.generate(400, 2.0, 1024, 41);
    let run_with = |threads: usize| {
        let mut fc = FleetConfig::new(cfg.clone(), "econoserve", "sharegpt");
        fc.oracle = false;
        fc.router = "least-kvc".to_string();
        fc.autoscaler = "reactive".to_string();
        fc.init_replicas = 2;
        fc.min_replicas = 2;
        fc.max_replicas = 3;
        fc.boot_latency = 5.0;
        fc.max_sim_time = 2_000.0;
        fc.threads = threads;
        fleet::run(&fc, &items)
    };
    let serial = run_with(1);
    let parallel = run_with(4);
    assert_eq!(
        serial.summary, parallel.summary,
        "prediction-fault FleetSummary diverged between serial and parallel stepping"
    );
    assert_eq!(
        format!("{:?}", serial.replicas),
        format!("{:?}", parallel.replicas),
        "prediction-fault replica lifecycle logs diverged"
    );
    assert_eq!(
        serial.metrics, parallel.metrics,
        "prediction-fault telemetry snapshot diverged between serial and parallel stepping"
    );

    use econoserve::telemetry::Snapshot;
    let snap = Snapshot::parse(&serial.metrics).expect("fleet metrics parse");
    let close = snap
        .value("econoserve_predictions_total", &[("verdict", "close")])
        .expect("predictions_total{close} present");
    let off = snap
        .value("econoserve_predictions_total", &[("verdict", "off")])
        .expect("predictions_total{off} present");
    assert!(close + off > 0.0, "no predictions issued — the pin is vacuous");
    let sum_pred: u64 = serial.per_replica.iter().map(|s| s.n_pred).sum();
    let sum_close: u64 = serial.per_replica.iter().map(|s| s.n_close).sum();
    assert_eq!(
        close + off,
        sum_pred as f64,
        "predictions_total != sum of per-replica summary n_pred"
    );
    assert_eq!(close, sum_close as f64, "predictions_total{{close}} != summary n_close");

    // Non-vacuity for the resilience machinery itself: regime-shift
    // under-provisioning was observed and the adaptive gauge moved off
    // the static sweet spot.
    let under = snap
        .value("econoserve_prediction_provision_total", &[("outcome", "under")])
        .expect("provision_total{under} present");
    assert!(under > 0.0, "regime-shift run saw no under-provisioning — pin is vacuous");
    assert!(
        snap.value("econoserve_padding_ratio", &[]).is_some(),
        "adaptive padding gauge missing"
    );
}

/// `exp::run_grid` with the faults axis emits bit-identical JSON rows
/// at 1 and 4 threads, and each fleet row carries its fault profile.
#[test]
fn chaos_sweep_rows_bit_identical_across_thread_counts() {
    use econoserve::exp::GridSpec;
    let mut spec = GridSpec {
        systems: vec!["econoserve".to_string()],
        models: vec!["opt-13b".to_string()],
        traces: vec!["alpaca".to_string()],
        rates: vec![4.0],
        seeds: vec![3],
        routers: vec!["least-kvc".to_string(), "round-robin".to_string()],
        autoscalers: vec!["reactive".to_string()],
        faults: vec!["none".to_string(), "crashes".to_string()],
        replicas: 2,
        duration: 8.0,
        max_time: 200.0,
        oracle: true,
        threads: 1,
        ..GridSpec::default()
    };
    let a = econoserve::exp::run_grid(&spec);
    spec.threads = 4;
    let b = econoserve::exp::run_grid(&spec);
    assert_eq!(a.rows, b.rows, "chaos sweep rows diverged across thread counts");
    assert_eq!(a.rows.len(), 4, "2 routers x 2 fault profiles");
    let chaos_rows = a
        .rows
        .iter()
        .filter(|r| r.get("faults").and_then(|f| f.as_str()) == Some("crashes"))
        .count();
    assert_eq!(chaos_rows, 2, "each router sweeps each fault profile once");
}
