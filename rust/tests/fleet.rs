//! Fleet-layer integration tests: router equivalence against the legacy
//! pre-sharded capacity model, seed reproducibility, autoscaler
//! invariants, the Fig 12 min-GPU port, the headline
//! cost-under-diurnal-load scenario, and the chaos suite (request
//! conservation under fault injection, health-aware vs health-blind
//! goodput retention).

use econoserve::config::{ModelProfile, SystemConfig};
use econoserve::coordinator::{harness, RunLimits};
use econoserve::fleet::{self, FleetConfig, FleetResult};
use econoserve::trace::{ArrivalProcess, TraceGen, TraceItem, TraceSpec};

fn test_cfg() -> SystemConfig {
    let mut cfg = SystemConfig::new(ModelProfile::opt_13b());
    cfg.t_p = 0.1;
    cfg.t_g = 0.025;
    // Keep runs bit-deterministic: no measured wall-clock charged into
    // the simulated clock.
    cfg.sched_time_scale = 0.0;
    cfg
}

fn sharegpt_items(n: usize, rate: f64, seed: u64) -> Vec<TraceItem> {
    TraceGen::new(TraceSpec::sharegpt()).generate(n, rate, 4096, seed)
}

fn diurnal_items(cfg: &SystemConfig, mean_rate: f64, period: f64, seed: u64) -> Vec<TraceItem> {
    let gen = TraceGen::new(TraceSpec::sharegpt());
    let process = ArrivalProcess::Diurnal { mean_rate, amplitude: 0.6, period };
    gen.generate_arrivals(process, 2.0 * period, cfg.profile.max_total_len, seed)
}

/// The ORIGINAL `cluster::replicas::replicated_run` goodput: round-robin
/// pre-sharding *by index*, one independent sim per shard, per-shard
/// spans, empty shards skipped. The production code now routes online
/// through the fleet, so this inline reference is what the equivalence
/// tests pin against.
fn legacy_presharded_goodput(
    cfg: &SystemConfig,
    items: &[TraceItem],
    k: usize,
    max_sim_time: f64,
) -> f64 {
    let mut shards: Vec<Vec<TraceItem>> = vec![Vec::new(); k];
    for (i, it) in items.iter().enumerate() {
        shards[i % k].push(*it);
    }
    let mut g = 0.0;
    for shard in shards {
        if shard.is_empty() {
            continue;
        }
        let res = harness::simulate(
            cfg,
            "econoserve",
            "sharegpt",
            &shard,
            true,
            RunLimits::for_time(max_sim_time),
        );
        g += res.summary.ssr * shard.len() as f64 / res.end_time.max(1e-9);
    }
    g
}

/// Lifecycle/routing invariants every fleet run must satisfy: requests
/// are only routed while a replica is Active, drains precede
/// retirements, and the serving size stays inside the configured bounds.
fn check_invariants(fc: &FleetConfig, res: &FleetResult) {
    let s = &res.summary;
    assert!(s.peak_replicas <= fc.max_replicas, "peak {} > max", s.peak_replicas);
    assert!(s.floor_replicas >= fc.min_replicas, "floor {} < min", s.floor_replicas);
    for (id, log) in res.replicas.iter().enumerate() {
        if let Some(f) = log.first_routed_at {
            assert!(
                f >= log.routable_at - 1e-9,
                "replica {id}: routed at {f} while booting (routable {})",
                log.routable_at
            );
        }
        if let (Some(l), Some(d)) = (log.last_routed_at, log.drain_at) {
            assert!(l <= d + 1e-9, "replica {id}: routed at {l} while draining (since {d})");
        }
        if let Some(r) = log.retired_at {
            let d = log.drain_at.expect("retirement requires a preceding drain");
            assert!(d <= r + 1e-9, "replica {id}: retired {r} before drain {d}");
        }
    }
    let routed: usize = res.replicas.iter().map(|l| l.routed).sum();
    assert_eq!(routed, s.n_routed, "per-replica routing counts disagree with the summary");
}

#[test]
fn static_round_robin_fleet_matches_presharded_legacy() {
    // The legacy `cluster::replicas::replicated_run` pre-sharded round
    // robin *by index* and summed per-shard goodputs. The fleet routes
    // round robin *at arrival time*; over a sorted trace the assignment
    // is identical, so aggregate goodput must agree within the slack the
    // differing time bases (per-shard span vs fleet span) introduce.
    let cfg = test_cfg();
    let items = sharegpt_items(300, 9.0, 11);
    let k = 3;
    let fleet_g = fleet::replicated_run(&cfg, "econoserve", "sharegpt", &items, true, k, 400.0)
        .summary
        .goodput_rps;
    let legacy_g = legacy_presharded_goodput(&cfg, &items, k, 400.0);
    let err = (fleet_g - legacy_g).abs() / legacy_g.max(1e-9);
    assert!(err < 0.15, "fleet {fleet_g:.3} vs legacy {legacy_g:.3} ({:.0}% off)", err * 100.0);
}

#[test]
fn fleet_runs_are_reproducible_per_seed() {
    // Same seed => identical fleet summary, under a randomized router
    // and a dynamic autoscaler (per-replica and router streams are all
    // derived from cfg.seed).
    let cfg = test_cfg();
    let items = diurnal_items(&cfg, 5.0, 120.0, 23);
    let mut fc = FleetConfig::new(cfg, "econoserve", "sharegpt");
    fc.oracle = true;
    fc.router = "power-of-two".to_string();
    fc.autoscaler = "reactive".to_string();
    fc.init_replicas = 2;
    fc.min_replicas = 1;
    fc.max_replicas = 3;
    fc.boot_latency = 6.0;
    fc.max_sim_time = 1_000.0;
    let a = fleet::run(&fc, &items);
    let b = fleet::run(&fc, &items);
    assert_eq!(a.summary.n_done, b.summary.n_done);
    assert_eq!(a.summary.slo_ok, b.summary.slo_ok);
    assert_eq!(a.summary.boots, b.summary.boots);
    assert_eq!(a.summary.retirements, b.summary.retirements);
    assert_eq!(a.summary.peak_replicas, b.summary.peak_replicas);
    assert_eq!(a.summary.end_time.to_bits(), b.summary.end_time.to_bits());
    assert_eq!(a.summary.gpu_hours.to_bits(), b.summary.gpu_hours.to_bits());
    assert_eq!(a.summary.mean_jct.to_bits(), b.summary.mean_jct.to_bits());
    for (x, y) in a.replicas.iter().zip(&b.replicas) {
        assert_eq!(x.routed, y.routed);
    }
    check_invariants(&fc, &a);
}

#[test]
fn every_router_and_autoscaler_combination_runs() {
    let cfg = test_cfg();
    let items = sharegpt_items(80, 5.0, 7);
    for router in fleet::all_routers() {
        for scaler in fleet::all_autoscalers() {
            let mut fc = FleetConfig::new(cfg.clone(), "econoserve", "sharegpt");
            fc.oracle = true;
            fc.router = router.to_string();
            fc.autoscaler = scaler.to_string();
            fc.init_replicas = 2;
            fc.min_replicas = if scaler == "static-k" { 2 } else { 1 };
            fc.max_replicas = 2;
            fc.boot_latency = 4.0;
            fc.max_sim_time = 600.0;
            let res = fleet::run(&fc, &items);
            assert_eq!(
                res.summary.n_done, items.len(),
                "{router}/{scaler}: not all requests completed"
            );
            assert_eq!(res.summary.n_routed, items.len());
            assert!(res.summary.gpu_hours > 0.0);
            check_invariants(&fc, &res);
        }
    }
}

#[test]
fn autoscaler_scales_up_under_pressure_and_drains_after() {
    // A diurnal curve whose peak overwhelms one replica: the reactive
    // scaler must boot capacity (boots > initial) and drain it again
    // once the trough comes (retirements > 0), while every lifecycle
    // invariant holds.
    let cfg = test_cfg();
    let items = diurnal_items(&cfg, 5.0, 150.0, 31);
    let mut fc = FleetConfig::new(cfg, "econoserve", "sharegpt");
    fc.oracle = true;
    fc.router = "least-kvc".to_string();
    fc.autoscaler = "reactive".to_string();
    fc.init_replicas = 1;
    fc.min_replicas = 1;
    fc.max_replicas = 3;
    fc.boot_latency = 6.0;
    fc.max_sim_time = 1_200.0;
    let res = fleet::run(&fc, &items);
    check_invariants(&fc, &res);
    assert!(res.summary.boots > 1, "no scale-up under a ~1.4x-capacity peak");
    assert!(res.summary.retirements > 0, "no drain-before-retire on the trough");
    assert!(res.summary.peak_replicas > 1);
    assert_eq!(res.summary.n_routed, items.len());
}

#[test]
fn fig12_min_gpu_search_matches_legacy_within_one_replica() {
    // The acceptance pin: the fleet-based static search reproduces the
    // legacy pre-sharded Fig 12 search within +/- 1 replica.
    let cfg = test_cfg();
    let items = sharegpt_items(200, 8.0, 13);
    let g2 = fleet::replicated_run(&cfg, "econoserve", "sharegpt", &items, true, 2, 300.0)
        .summary
        .goodput_rps;
    let target = g2 * 0.9;
    let max_k = 4;
    let fleet_k = fleet::min_replicas_for_goodput(
        &cfg,
        "econoserve",
        "sharegpt",
        &items,
        true,
        target,
        max_k,
        300.0,
    )
    .expect("feasible within 4 replicas");
    // Legacy feasibility: index pre-sharding, per-shard spans.
    let legacy_k = (1..=max_k)
        .find(|&k| legacy_presharded_goodput(&cfg, &items, k, 300.0) >= target)
        .expect("legacy search feasible");
    assert!(
        fleet_k.abs_diff(legacy_k) <= 1,
        "fleet needs {fleet_k} replicas, legacy search found {legacy_k}"
    );
}

#[test]
fn diurnal_autoscaling_saves_gpu_hours_at_equal_slo() {
    // The headline scenario (CLI: `econoserve fleet --workload diurnal
    // --autoscaler forecast --compare-static`): under a day-curve, the
    // forecast autoscaler must match the static peak fleet's SLO
    // attainment while consuming measurably fewer GPU-hours.
    let cfg = test_cfg();
    let items = diurnal_items(&cfg, 6.0, 180.0, 42);
    let mut dynamic = FleetConfig::new(cfg.clone(), "econoserve", "sharegpt");
    dynamic.oracle = true;
    dynamic.router = "least-kvc".to_string();
    dynamic.autoscaler = "forecast".to_string();
    dynamic.init_replicas = 2;
    dynamic.min_replicas = 1;
    dynamic.max_replicas = 3;
    dynamic.boot_latency = 6.0;
    dynamic.control_interval = 10.0;
    dynamic.max_sim_time = 2_000.0;
    let mut static_peak = dynamic.clone();
    static_peak.autoscaler = "static-k".to_string();
    static_peak.init_replicas = 3;
    static_peak.min_replicas = 3;
    static_peak.boot_latency = 0.0;
    let dy = fleet::run(&dynamic, &items).summary;
    let st = fleet::run(&static_peak, &items).summary;
    assert!(
        dy.ssr + 0.02 >= st.ssr,
        "forecast SSR {:.3} fell behind static-peak {:.3}",
        dy.ssr,
        st.ssr
    );
    assert!(
        dy.gpu_hours < 0.85 * st.gpu_hours,
        "no meaningful GPU-hour saving: {} vs {}",
        dy.gpu_hours,
        st.gpu_hours
    );
    assert!(
        dy.goodput_per_gpu_hour > st.goodput_per_gpu_hour,
        "cost efficiency did not improve: {} vs {}",
        dy.goodput_per_gpu_hour,
        st.goodput_per_gpu_hour
    );
}

// ---------------------------------------------------------------------
// Chaos suite: deterministic fault injection
// ---------------------------------------------------------------------

fn chaos_cfg(cfg: &SystemConfig, profile: &str) -> FleetConfig {
    let mut fc = FleetConfig::new(cfg.clone(), "econoserve", "sharegpt");
    fc.oracle = true;
    fc.router = "least-kvc".to_string();
    fc.autoscaler = "reactive".to_string();
    fc.init_replicas = 2;
    fc.min_replicas = 2;
    fc.max_replicas = 4;
    fc.boot_latency = 5.0;
    fc.control_interval = 5.0;
    fc.max_sim_time = 5_000.0;
    fc.faults = profile.to_string();
    fc
}

#[test]
fn chaos_conserves_requests_under_every_profile() {
    // The generalized accounting identity: every submitted request ends
    // in exactly one terminal state — completed, lost to a crash with no
    // retry budget left, or aborted by a guardrail (deadline abort or
    // brownout shed) — under every shipped fault profile crossed with
    // every guardrail mode. Health-blind coverage rides along on the
    // modes that exercised it before guardrails existed.
    let cfg = test_cfg();
    let items = diurnal_items(&cfg, 3.0, 200.0, 17);
    for profile in fleet::all_profiles() {
        for mode in econoserve::reliability::all_modes() {
            let aware_values: &[bool] =
                if mode == "off" || mode == "full" { &[true, false] } else { &[true] };
            for &health_aware in aware_values {
                let mut fc = chaos_cfg(&cfg, profile);
                fc.health_aware = health_aware;
                fc.guardrails = mode.to_string();
                let res = fleet::run(&fc, &items);
                let s = &res.summary;
                assert_eq!(
                    s.n_total,
                    s.n_done + s.faults.lost + s.faults.aborted,
                    "{profile}/{mode} (aware={health_aware}): conservation broke \
                     (done {} + lost {} + aborted {} != submitted {})",
                    s.n_done,
                    s.faults.lost,
                    s.faults.aborted,
                    s.n_total
                );
                assert!(s.peak_replicas <= fc.max_replicas);
                let routed: usize = res.replicas.iter().map(|l| l.routed).sum();
                assert_eq!(routed, s.n_routed, "{profile}/{mode}: routing counts disagree");
                if profile == "none" && mode == "off" {
                    assert!(s.faults.is_zero(), "fault-free run tallied faults");
                    assert_eq!(s.n_done, s.n_total);
                }
            }
        }
    }
}

#[test]
fn chaos_runs_are_reproducible_per_seed() {
    // Same seed => bit-identical FleetSummary under the heaviest
    // profile (crashes + zone outages + stragglers + flaky boots).
    let cfg = test_cfg();
    let items = diurnal_items(&cfg, 3.0, 200.0, 19);
    let fc = chaos_cfg(&cfg, "full-chaos");
    let a = fleet::run(&fc, &items);
    let b = fleet::run(&fc, &items);
    assert_eq!(a.summary, b.summary, "chaos run not reproducible per seed");
    assert!(!a.summary.faults.is_zero(), "full-chaos run saw no faults");
    for (x, y) in a.replicas.iter().zip(&b.replicas) {
        assert_eq!(x.routed, y.routed);
        assert_eq!(x.rerouted, y.rerouted);
        assert_eq!(x.crashed_at, y.crashed_at);
    }
    // And with every guardrail armed on top: retry jitter, hedge races
    // and brownout tiers are all seed-derived, so the summary must stay
    // bit-identical run to run.
    let mut gfc = chaos_cfg(&cfg, "full-chaos");
    gfc.guardrails = "full".to_string();
    let ga = fleet::run(&gfc, &items);
    let gb = fleet::run(&gfc, &items);
    assert_eq!(ga.summary, gb.summary, "guardrail chaos run not reproducible per seed");
}

#[test]
fn health_aware_fleet_retains_more_goodput_under_chaos() {
    // The acceptance pin: health-aware routing + reactive re-provisioning
    // must strictly beat a health-blind static fleet (corpses stay in the
    // routing table looking idle; losses are never replaced) on both
    // goodput and SSR, under lone crashes and correlated zone outages.
    let cfg = test_cfg();
    let items = diurnal_items(&cfg, 4.0, 200.0, 29);
    for profile in ["crashes", "zone-outage"] {
        let mut aware = chaos_cfg(&cfg, profile);
        aware.max_replicas = 3;
        let mut blind = aware.clone();
        blind.health_aware = false;
        blind.autoscaler = "static-k".to_string();
        blind.init_replicas = 3;
        blind.min_replicas = 3;
        let a = fleet::run(&aware, &items).summary;
        let b = fleet::run(&blind, &items).summary;
        assert!(a.faults.crashes > 0, "{profile}: no faults fired in the window");
        assert!(
            a.goodput_rps > b.goodput_rps,
            "{profile}: health-aware goodput {:.3} did not beat blind {:.3}",
            a.goodput_rps,
            b.goodput_rps
        );
        assert!(
            a.ssr > b.ssr,
            "{profile}: health-aware SSR {:.3} did not beat blind {:.3}",
            a.ssr,
            b.ssr
        );
    }
}

#[test]
fn chaos_run_compares_against_a_fault_free_baseline() {
    // `fleet::chaos_run` (the `econoserve fleet --chaos` surface) pairs a
    // chaos run with its own fault-free twin: the baseline must tally no
    // faults and complete everything; retentions must be well-defined.
    let cfg = test_cfg();
    let items = diurnal_items(&cfg, 3.0, 200.0, 37);
    let fc = chaos_cfg(&cfg, "crashes");
    let out = fleet::chaos_run(&fc, &items);
    assert!(out.baseline.faults.is_zero(), "baseline run saw faults");
    assert_eq!(out.baseline.n_done, out.baseline.n_total);
    assert!(out.chaos.faults.crashes > 0, "chaos run saw no crashes");
    assert!(out.goodput_retention() > 0.0 && out.goodput_retention().is_finite());
    assert!(out.ssr_retention() > 0.0 && out.ssr_retention().is_finite());
}

// ---------------------------------------------------------------------
// Reliability guardrails
// ---------------------------------------------------------------------

#[test]
fn guardrails_beat_bare_rerouting_under_crashes() {
    // The acceptance pin: under the crashes profile with health-aware
    // routing, retry+hedge+abort must strictly beat guardrails-off on
    // BOTH goodput and SSR. The mechanism: deadline aborts free KVC held
    // by provably hopeless requests (they could never land in-SLO, so
    // culling them costs nothing and speeds every survivor), hedges let
    // a crash-doomed request's copy finish elsewhere, and retries put
    // crash-displaced work back with its ORIGINAL deadline. A capacity
    // pinch (diurnal peak over a 2-replica fleet, slow reboots) makes
    // the freed KVC matter.
    let cfg = test_cfg();
    let items = diurnal_items(&cfg, 4.0, 240.0, 47);
    let mut off = chaos_cfg(&cfg, "crashes");
    off.init_replicas = 2;
    off.min_replicas = 2;
    off.max_replicas = 2;
    off.boot_latency = 25.0;
    let mut guarded = off.clone();
    guarded.guardrails = "retry+hedge+abort".to_string();
    let a = fleet::run(&off, &items).summary;
    let g = fleet::run(&guarded, &items).summary;

    assert!(a.faults.crashes > 0, "no crashes fired in the window");
    assert_eq!(a.faults.retried, 0, "guardrails-off run retried requests");
    assert_eq!(a.faults.aborted, 0, "guardrails-off run aborted requests");
    assert!(
        g.goodput_rps > a.goodput_rps,
        "guardrails goodput {:.3} did not beat off {:.3}",
        g.goodput_rps,
        a.goodput_rps
    );
    assert!(g.ssr > a.ssr, "guardrails SSR {:.3} did not beat off {:.3}", g.ssr, a.ssr);
    assert!(g.faults.recovered > 0, "no displaced request was recovered by a retry");
    assert!(g.faults.retried >= g.faults.recovered);
    // The generalized conservation identity holds exactly on both sides.
    assert_eq!(a.n_total, a.n_done + a.faults.lost + a.faults.aborted);
    assert_eq!(g.n_total, g.n_done + g.faults.lost + g.faults.aborted);
}

// ---------------------------------------------------------------------
// Prediction-fault resilience
// ---------------------------------------------------------------------

#[test]
fn adaptive_headroom_contains_prediction_chaos() {
    // The acceptance pin: under predictor chaos — stale regime shifts,
    // and the moderated everything-at-once profile — the adaptive
    // headroom controller + per-iteration eviction budget must strictly
    // beat the static sweet-spot padding on BOTH SSR and the KVC
    // allocation-failure count, while keeping overrun evictions bounded
    // per iteration. The mechanism: under-scaled predictions make hosts
    // outrun their reserved spans, plow through the guests riding in
    // their tails (mass evictions, lost KV, recompute), and drain the
    // reserved pool with rescue extensions that then fail — the
    // adaptive controller instead steers the padding toward the
    // observed error quantile so reservations are honest up-front, and
    // the budget turns any residual eviction burst into backpressure.
    use econoserve::telemetry::Snapshot;
    let cfg0 = test_cfg();
    let items = diurnal_items(&cfg0, 3.5, 240.0, 61);
    let exhausted = |res: &FleetResult| {
        Snapshot::parse(&res.metrics)
            .expect("fleet metrics parse")
            .value("econoserve_kvc_alloc_total", &[("outcome", "exhausted")])
            .unwrap_or(0.0)
    };
    for profile in ["regime-shift", "full-chaos"] {
        let run = |headroom: &str| {
            let mut cfg = test_cfg();
            cfg.predictor_faults = profile.to_string();
            cfg.headroom = headroom.to_string();
            let mut fc = FleetConfig::new(cfg, "econoserve", "sharegpt");
            fc.oracle = true;
            fc.router = "least-kvc".to_string();
            fc.autoscaler = "static-k".to_string();
            fc.init_replicas = 2;
            fc.min_replicas = 2;
            fc.max_replicas = 2;
            fc.boot_latency = 0.0;
            fc.max_sim_time = 5_000.0;
            fleet::run(&fc, &items)
        };
        let st = run("static");
        let ad = run("adaptive");

        // Non-vacuity: the chaos actually bit on the static side —
        // under-provisioned completions and overrun evictions occurred.
        let snap = Snapshot::parse(&st.metrics).expect("static metrics parse");
        let under = snap
            .value("econoserve_prediction_provision_total", &[("outcome", "under")])
            .unwrap_or(0.0);
        assert!(under > 0.0, "{profile}: static run saw no under-provisioning — pin is vacuous");
        let st_evictions: u64 = st.per_replica.iter().map(|s| s.pipeline_evictions).sum();
        assert!(st_evictions > 0, "{profile}: static run saw no overrun evictions — pin is vacuous");
        let (xs, xa) = (exhausted(&st), exhausted(&ad));
        assert!(xs > 0.0, "{profile}: static run saw no allocation failures — pin is vacuous");

        assert!(
            ad.summary.ssr > st.summary.ssr,
            "{profile}: adaptive SSR {:.3} did not beat static {:.3}",
            ad.summary.ssr,
            st.summary.ssr
        );
        assert!(
            xa < xs,
            "{profile}: adaptive allocation failures {xa} did not drop below static {xs}"
        );
        // The eviction budget holds on every replica: no iteration may
        // evict more than the configured budget (4; halved under tier-2
        // escalation, never raised).
        for (i, s) in ad.per_replica.iter().enumerate() {
            assert!(
                s.max_iter_evictions <= 4,
                "{profile}: replica {i} evicted {} guests in one iteration (budget 4)",
                s.max_iter_evictions
            );
        }
        // Both fleets served the full offered load.
        assert_eq!(st.summary.n_routed, items.len(), "{profile}: static run dropped arrivals");
        assert_eq!(ad.summary.n_routed, items.len(), "{profile}: adaptive run dropped arrivals");
    }
}

#[test]
fn hedge_outcomes_reconcile_and_deadlines_survive_retries() {
    // Hedging under full chaos: every launched hedge resolves to exactly
    // one of won/lost/duplicate (no copy leaks), and retried requests
    // keep their original deadline — a recovered request that lands
    // in-SLO does so against arrival + slo_budget(rl), not against its
    // re-injection time (checked indirectly: SSR can only count n_total
    // requests, and the identity stays exact while hedges duplicate
    // work).
    let cfg = test_cfg();
    let items = diurnal_items(&cfg, 3.0, 200.0, 53);
    let mut fc = chaos_cfg(&cfg, "full-chaos");
    fc.guardrails = "retry+hedge".to_string();
    let res = fleet::run(&fc, &items);
    let s = &res.summary;
    assert_eq!(s.n_total, s.n_done + s.faults.lost + s.faults.aborted);
    assert_eq!(s.n_total, items.len());
    assert!(s.slo_ok <= s.n_done, "SLO-ok exceeded completions: duplicate leaked");
    assert!(s.faults.hedges_won <= s.faults.retried + items.len());
    check_invariants(&fc, &res);
}
