//! Integration test: the rust PJRT runtime must reproduce the python
//! stack's golden transcript (greedy decode) from the AOT artifacts.
//!
//! Requires `make artifacts` (skipped with a loud message otherwise).

use econoserve::runtime::{load_golden, PjrtModel};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP pjrt_golden: run `make artifacts` first ({:?} missing)", dir);
        None
    }
}

#[test]
fn golden_transcript_matches_python() {
    let Some(dir) = artifacts_dir() else { return };
    let golden = load_golden(&dir).expect("golden.json");
    let mut model = PjrtModel::load(&dir).expect("load artifacts");

    // Prefill the golden prompt.
    let (logits, state_1) = model.prefill(&golden.prompt).expect("prefill");
    let l2: f64 = logits.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>().sqrt();
    let rel = (l2 - golden.prefill_logits_l2).abs() / golden.prefill_logits_l2.max(1e-9);
    assert!(rel < 1e-3, "prefill logits L2 {l2} vs python {}", golden.prefill_logits_l2);

    // Greedy decode must reproduce the exact token ids.
    model.insert(&state_1, 0).expect("insert");
    let b = model.dims.decode_slots;
    let mut lens = vec![0i32; b];
    let mut toks = vec![0i32; b];
    let mut cur = PjrtModel::argmax(&logits);
    let mut got = vec![cur];
    let mut len = golden.prompt_len as i32;
    for _ in 1..golden.steps {
        lens[0] = len;
        toks[0] = cur;
        let logits = model.decode_step(&lens, &toks).expect("decode");
        cur = PjrtModel::argmax(&logits[0]);
        got.push(cur);
        len += 1;
    }
    assert_eq!(got, golden.generated, "greedy tokens diverge from python");
}

#[test]
fn dead_slots_do_not_disturb_live_ones() {
    let Some(dir) = artifacts_dir() else { return };
    let golden = load_golden(&dir).expect("golden.json");
    let mut model = PjrtModel::load(&dir).expect("load artifacts");

    // Run the same transcript but with a second live slot occupied by a
    // different prompt: slot 0's tokens must be unchanged.
    let (logits0, s0) = model.prefill(&golden.prompt).expect("prefill 0");
    let other: Vec<i32> = golden.prompt.iter().map(|t| (t % 97) + 1).collect();
    let (logits1, s1) = model.prefill(&other).expect("prefill 1");
    model.insert(&s0, 0).expect("insert 0");
    model.insert(&s1, 1).expect("insert 1");

    let b = model.dims.decode_slots;
    let mut lens = vec![0i32; b];
    let mut toks = vec![0i32; b];
    let mut cur0 = PjrtModel::argmax(&logits0);
    let mut cur1 = PjrtModel::argmax(&logits1);
    let mut got = vec![cur0];
    let mut len0 = golden.prompt_len as i32;
    let mut len1 = other.len() as i32;
    for _ in 1..golden.steps {
        lens[0] = len0;
        toks[0] = cur0;
        lens[1] = len1;
        toks[1] = cur1;
        let logits = model.decode_step(&lens, &toks).expect("decode");
        cur0 = PjrtModel::argmax(&logits[0]);
        cur1 = PjrtModel::argmax(&logits[1]);
        got.push(cur0);
        len0 += 1;
        len1 += 1;
    }
    assert_eq!(got, golden.generated, "batch interference detected");
}
