//! Property-based tests over the coordinator's core invariants
//! (in-tree prop kit; see util::prop for replay instructions).

use econoserve::config::{ModelProfile, SystemConfig};
use econoserve::coordinator::{run, RunLimits};
use econoserve::engine::SimEngine;
use econoserve::kvc::pipeline::candidate_slots;
use econoserve::kvc::{by_name as alloc_by_name, Allocator, Demand, ReserveClass};
use econoserve::ordering::best_fit_leq;
use econoserve::predictor::{OraclePredictor, SimPredictor};
use econoserve::trace::TraceItem;
use econoserve::util::prop::{run_prop, sized, vec_of};
use econoserve::util::rng::Rng;

// ---------------------------------------------------------------------
// KVC allocators (the block pool is private; everything goes through the
// first-class Allocator API)
// ---------------------------------------------------------------------

#[test]
fn kvc_allocator_accounting_balances_under_random_ops() {
    run_prop("kvc_accounting", 200, |rng| {
        let cap = 64 + sized(rng, 4000) as u32;
        let bs = [8u32, 16, 32, 64][rng.range_usize(0, 3)];
        let reserve = (rng.range_u64(0, (cap / 4) as u64) as u32).min(cap / bs * bs);
        let name = ["block", "exact"][rng.range_usize(0, 1)];
        let mut a = alloc_by_name(name, cap, bs, reserve).unwrap();
        let mut live: Vec<usize> = Vec::new();
        for op in 0..sized(rng, 200) {
            match rng.range_u64(0, 3) {
                0 => {
                    let id = 1000 + op;
                    let want = 1 + sized(rng, 300) as u32;
                    let class = if rng.chance(0.5) {
                        ReserveClass::Normal
                    } else {
                        ReserveClass::Reserved
                    };
                    if a.extend(id, want, class).ok() {
                        // Write at most the leased capacity.
                        let capn = a.allocated(id) - a.written(id);
                        a.record_write(id, rng.range_u64(0, capn as u64) as u32);
                        live.push(id);
                    }
                }
                1 => {
                    if !live.is_empty() {
                        let idx = rng.range_usize(0, live.len() - 1);
                        let id = live.swap_remove(idx);
                        a.release(id);
                    }
                }
                _ => {
                    if !live.is_empty() {
                        let idx = rng.range_usize(0, live.len() - 1);
                        a.shrink_to_written(live[idx]);
                    }
                }
            }
            a.check_invariants();
            assert!(a.total_allocated() <= a.capacity_tokens() as u64);
            assert!(a.total_written() <= a.total_allocated());
            assert_eq!(a.stats().implicit_grows, 0, "bounded writes need no rescue");
        }
        for id in live {
            a.release(id);
        }
        a.check_invariants();
        assert_eq!(a.total_allocated(), 0, "all blocks must return");
    });
}

#[test]
fn kvc_reserve_never_consumed_by_normal_class() {
    run_prop("kvc_reserve", 100, |rng| {
        let cap = 1024u32;
        let bs = 32u32;
        let reserve = (rng.range_u64(1, 8) * 32) as u32;
        let mut a = alloc_by_name("block", cap, bs, reserve).unwrap();
        // Fill with Normal-class leases as far as possible.
        let mut id = 0;
        while a.extend(id, 1 + sized(rng, 128) as u32, ReserveClass::Normal).ok() {
            id += 1;
            assert!(id < 1000);
        }
        // The reserve must still be intact.
        assert!(a.free_tokens(ReserveClass::Reserved) >= reserve);
    });
}

#[test]
fn pipelined_exact_never_overcommits() {
    // The satellite property: under arbitrary interleavings of hosting,
    // guest/host writes, overrun evictions, adoption and release,
    // `Pipelined<ExactAlloc>` never reports written > allocated capacity.
    run_prop("pipelined_overcommit", 150, |rng| {
        let cap = 2048u32;
        let mut a = alloc_by_name("pipelined-exact", cap, 32, 0).unwrap();
        // (id, span, head) per live host; (id, slot_len, written) per guest.
        let mut hosts: Vec<(usize, u32, u32)> = Vec::new();
        let mut guests: Vec<(usize, u32, u32)> = Vec::new();
        let mut next_id = 1usize;
        for _ in 0..sized(rng, 300) {
            match rng.range_u64(0, 4) {
                0 => {
                    // Admit a new host span.
                    let predicted = 8 + sized(rng, 256) as u32;
                    let d = Demand { immediate: 0, predicted, max_total: cap };
                    if a.admit(next_id, d, ReserveClass::Normal).ok() {
                        hosts.push((next_id, predicted + 1, 0));
                        next_id += 1;
                    }
                }
                1 => {
                    // Lend a slot from a random host.
                    if hosts.is_empty() {
                        continue;
                    }
                    let (h, span, head) = hosts[rng.range_usize(0, hosts.len() - 1)];
                    let target = a.lend_capacity(h, span, head, 0.1);
                    if target == 0 {
                        continue;
                    }
                    let rl = 1 + rng.range_u64(0, (target - 1) as u64) as u32;
                    if a.lend(h, span, head, 0.1, next_id, rl).ok() {
                        guests.push((next_id, rl, 0));
                        next_id += 1;
                    }
                }
                2 => {
                    // Advance a host's write head, evicting overrun guests
                    // first (the world's sweep protocol).
                    if hosts.is_empty() {
                        continue;
                    }
                    let idx = rng.range_usize(0, hosts.len() - 1);
                    let (h, span, head) = hosts[idx];
                    if head >= span {
                        continue;
                    }
                    for g in a.overrun_guests(h, head + 1) {
                        a.drop_guest(g);
                        guests.retain(|(id, _, _)| *id != g);
                    }
                    a.record_write(h, 1);
                    hosts[idx].2 += 1;
                }
                3 => {
                    // A guest writes into its borrowed slot.
                    if guests.is_empty() {
                        continue;
                    }
                    let idx = rng.range_usize(0, guests.len() - 1);
                    let (g, len, written) = guests[idx];
                    if written < len {
                        a.record_write(g, 1);
                        guests[idx].2 += 1;
                    } else if a.adopt(g, written + 8).ok() {
                        // Slot full: migrate onto an own lease.
                        guests.remove(idx);
                    }
                }
                _ => {
                    // Release a random host; orphans lose their space.
                    if hosts.is_empty() {
                        continue;
                    }
                    let idx = rng.range_usize(0, hosts.len() - 1);
                    let (h, _, _) = hosts.remove(idx);
                    let rel = a.release(h);
                    for g in rel.orphans {
                        a.drop_guest(g);
                        guests.retain(|(id, _, _)| *id != g);
                    }
                }
            }
            a.check_invariants();
            assert!(
                a.total_written() <= a.total_allocated(),
                "pipelined allocator overcommitted: written {} > allocated {}",
                a.total_written(),
                a.total_allocated()
            );
        }
    });
}

// ---------------------------------------------------------------------
// KVC pipelining geometry
// ---------------------------------------------------------------------

#[test]
fn pipeline_slots_nested_or_disjoint() {
    run_prop("pipe_slots", 200, |rng| {
        let span = 2 + sized(rng, 4096) as u32;
        let min_len = 1 + sized(rng, 64) as u32;
        let depth = 1 + rng.range_u64(0, 5) as u32;
        let slots = candidate_slots(span, min_len, depth);
        for s in &slots {
            assert!(s.len >= min_len);
            assert!(s.offset + s.len <= span, "slot out of span: {s:?} span={span}");
        }
        for a in &slots {
            for b in &slots {
                if a == b {
                    continue;
                }
                let (ae, be) = (a.offset + a.len, b.offset + b.len);
                let disjoint = ae <= b.offset || be <= a.offset;
                let nested = (a.offset >= b.offset && ae <= be) || (b.offset >= a.offset && be <= ae);
                assert!(disjoint || nested, "{a:?} vs {b:?}");
            }
        }
    });
}

#[test]
fn best_fit_matches_linear_reference() {
    run_prop("best_fit", 300, |rng| {
        let mut lens = vec_of(rng, 40, |r| r.range_u64(1, 1000) as u32);
        lens.sort_unstable_by(|a, b| b.cmp(a)); // descending
        let pairs: Vec<(u32, usize)> = lens.iter().copied().zip(0..).collect();
        let cap = rng.range_u64(0, 1200) as u32;
        let got = best_fit_leq(&pairs, cap);
        let want = pairs.iter().position(|(l, _)| *l <= cap);
        assert_eq!(got, want, "cap={cap} lens={lens:?}");
    });
}

// ---------------------------------------------------------------------
// End-to-end scheduler invariants on random workloads
// ---------------------------------------------------------------------

fn random_items(rng: &mut Rng, n: usize, max_len: u32) -> Vec<TraceItem> {
    let mut t = 0.0;
    (0..n)
        .map(|_| {
            t += rng.exponential(5.0);
            let prompt_len = 1 + sized(rng, (max_len / 3) as usize) as u32;
            let true_rl =
                1 + sized(rng, (max_len - prompt_len).min(300) as usize) as u32;
            TraceItem { arrival: t, prompt_len, true_rl }
        })
        .collect()
}

fn mini_cfg(kvc_tokens: u64) -> SystemConfig {
    let mut profile = ModelProfile::opt_13b();
    profile.kvc_bytes = 819_200 * kvc_tokens;
    profile.max_total_len = 1024;
    let mut cfg = SystemConfig::new(profile);
    cfg.t_p = 0.05;
    cfg.t_g = 0.022;
    cfg
}

#[test]
fn every_scheduler_conserves_and_completes() {
    run_prop("sched_conservation", 20, |rng| {
        let n = 12 + sized(rng, 30);
        let items = random_items(rng, n, 900);
        let systems = econoserve::sched::all_systems();
        let sys_name = systems[rng.range_usize(0, systems.len() - 1)];
        let cfg = mini_cfg(4096);
        let pred = Box::new(SimPredictor::new(0.15, cfg.block_size, rng.next_u64()));
        let mut world = econoserve::core::world::World::new(cfg, &items, pred);
        let sys = econoserve::sched::by_name(sys_name).unwrap();
        world.set_allocator(sys.alloc);
        let mut sched = sys.sched;
        let engine = SimEngine::new();
        let res = run(&mut world, sched.as_mut(), &engine, RunLimits::default());
        assert_eq!(res.summary.n_done, items.len(), "{sys_name} lost requests");
        // Conservation: exact token counts, KVC fully returned.
        for rec in &world.recs {
            assert_eq!(rec.generated, rec.req.true_rl, "{sys_name}: wrong token count");
            assert_eq!(rec.prompt_done, rec.req.prompt_len);
            assert!(rec.done_at.unwrap() >= rec.req.arrival);
        }
        assert_eq!(world.kvc().total_allocated(), 0, "{sys_name} leaked KVC");
        world.kvc().check_invariants();
        assert_eq!(world.kvc().guest_count(), 0);
    });
}

#[test]
fn econoserve_oracle_never_evicts_guests() {
    run_prop("oracle_no_evictions", 15, |rng| {
        let n = 20 + sized(rng, 25);
        let items = random_items(rng, n, 700);
        let mut cfg = mini_cfg(3000);
        cfg.padding_ratio = 0.10;
        let pred = Box::new(OraclePredictor::new(cfg.block_size));
        let mut world = econoserve::core::world::World::new(cfg, &items, pred);
        let sys = econoserve::sched::by_name("econoserve").unwrap();
        world.set_allocator(sys.alloc);
        let mut sched = sys.sched;
        let engine = SimEngine::new();
        let res = run(&mut world, sched.as_mut(), &engine, RunLimits::default());
        assert_eq!(res.summary.n_done, items.len());
        // Exact predictions + buffer: the Fig 7 invariant means a hosted
        // GT always completes before its host's write head arrives.
        assert_eq!(world.col.pipeline_evictions, 0, "guest evicted under oracle predictions");
    });
}

#[test]
fn exact_allocation_never_fails_for_multires() {
    run_prop("multires_no_fail", 15, |rng| {
        let n = 15 + sized(rng, 25);
        let items = random_items(rng, n, 700);
        let cfg = mini_cfg(4096);
        let pred = Box::new(OraclePredictor::new(cfg.block_size));
        let mut world = econoserve::core::world::World::new(cfg, &items, pred);
        let sys = econoserve::sched::by_name("multires").unwrap();
        world.set_allocator(sys.alloc);
        let mut sched = sys.sched;
        let engine = SimEngine::new();
        let res = run(&mut world, sched.as_mut(), &engine, RunLimits::default());
        assert_eq!(res.summary.n_done, items.len());
        assert_eq!(world.kvc().stats().failures, 0);
    });
}

#[test]
fn deterministic_given_seed() {
    run_prop("determinism", 8, |rng| {
        let seed = rng.next_u64();
        let go = || {
            let mut r = Rng::new(seed);
            let items = random_items(&mut r, 25, 800);
            let mut cfg = mini_cfg(4096);
            // Scheduling time is measured wall-clock; charge none so the
            // simulated clock is bit-deterministic for this test.
            cfg.sched_time_scale = 0.0;
            let pred = Box::new(SimPredictor::new(0.15, cfg.block_size, seed));
            let mut world = econoserve::core::world::World::new(cfg, &items, pred);
            let sys = econoserve::sched::by_name("econoserve").unwrap();
            world.set_allocator(sys.alloc);
            let mut sched = sys.sched;
            let engine = SimEngine::new();
            let res = run(&mut world, sched.as_mut(), &engine, RunLimits::default());
            (res.summary.n_done, res.summary.iterations, format!("{:.9}", res.summary.mean_jct))
        };
        let a = go();
        let b = go();
        assert_eq!(a, b, "simulation must be bit-deterministic at sched_time_scale=0");
    });
}
