//! Property-based tests over the coordinator's core invariants
//! (in-tree prop kit; see util::prop for replay instructions).

use econoserve::config::{ModelProfile, SystemConfig};
use econoserve::coordinator::{run, RunLimits};
use econoserve::engine::SimEngine;
use econoserve::kvc::pipeline::candidate_slots;
use econoserve::kvc::{BlockPool, Priority};
use econoserve::ordering::best_fit_leq;
use econoserve::predictor::{OraclePredictor, SimPredictor};
use econoserve::trace::TraceItem;
use econoserve::util::prop::{run_prop, sized, vec_of};
use econoserve::util::rng::Rng;

// ---------------------------------------------------------------------
// KVC block pool
// ---------------------------------------------------------------------

#[test]
fn kvc_pool_accounting_balances_under_random_ops() {
    run_prop("kvc_accounting", 200, |rng| {
        let cap = 64 + sized(rng, 4000) as u32;
        let bs = [8u32, 16, 32, 64][rng.range_usize(0, 3)];
        let reserve = rng.range_u64(0, (cap / 4) as u64) as u32;
        let mut pool = BlockPool::new(cap, bs, reserve.min(cap / bs * bs));
        let mut live: Vec<usize> = Vec::new();
        for op in 0..sized(rng, 200) {
            match rng.range_u64(0, 3) {
                0 => {
                    let id = 1000 + op;
                    let want = 1 + sized(rng, 300) as u32;
                    let prio =
                        if rng.chance(0.5) { Priority::Normal } else { Priority::Reserved };
                    if pool.alloc_tokens(id, want, prio).is_ok() {
                        // Write at most the allocated capacity.
                        let capn = pool.allocated_tokens(id) - pool.written_tokens(id);
                        pool.write_tokens(id, rng.range_u64(0, capn as u64) as u32);
                        live.push(id);
                    }
                }
                1 => {
                    if !live.is_empty() {
                        let idx = rng.range_usize(0, live.len() - 1);
                        let id = live.swap_remove(idx);
                        pool.release(id);
                    }
                }
                _ => {
                    if !live.is_empty() {
                        let idx = rng.range_usize(0, live.len() - 1);
                        pool.trim_to_written(live[idx]);
                    }
                }
            }
            pool.check_invariants();
            assert!(pool.total_allocated() <= pool.capacity_tokens() as u64);
            assert!(pool.total_written() <= pool.total_allocated());
        }
        for id in live {
            pool.release(id);
        }
        pool.check_invariants();
        assert_eq!(pool.total_allocated(), 0, "all blocks must return");
    });
}

#[test]
fn kvc_reserve_never_consumed_by_normal() {
    run_prop("kvc_reserve", 100, |rng| {
        let cap = 1024u32;
        let bs = 32u32;
        let reserve = (rng.range_u64(1, 8) * 32) as u32;
        let mut pool = BlockPool::new(cap, bs, reserve);
        // Fill with Normal allocations as far as possible.
        let mut id = 0;
        while pool.alloc_tokens(id, 1 + sized(rng, 128) as u32, Priority::Normal).is_ok() {
            id += 1;
            assert!(id < 1000);
        }
        // The reserve must still be intact.
        assert!(pool.free_tokens(Priority::Reserved) >= reserve);
    });
}

// ---------------------------------------------------------------------
// KVC pipelining geometry
// ---------------------------------------------------------------------

#[test]
fn pipeline_slots_nested_or_disjoint() {
    run_prop("pipe_slots", 200, |rng| {
        let span = 2 + sized(rng, 4096) as u32;
        let min_len = 1 + sized(rng, 64) as u32;
        let depth = 1 + rng.range_u64(0, 5) as u32;
        let slots = candidate_slots(span, min_len, depth);
        for s in &slots {
            assert!(s.len >= min_len);
            assert!(s.offset + s.len <= span, "slot out of span: {s:?} span={span}");
        }
        for a in &slots {
            for b in &slots {
                if a == b {
                    continue;
                }
                let (ae, be) = (a.offset + a.len, b.offset + b.len);
                let disjoint = ae <= b.offset || be <= a.offset;
                let nested = (a.offset >= b.offset && ae <= be) || (b.offset >= a.offset && be <= ae);
                assert!(disjoint || nested, "{a:?} vs {b:?}");
            }
        }
    });
}

#[test]
fn best_fit_matches_linear_reference() {
    run_prop("best_fit", 300, |rng| {
        let mut lens = vec_of(rng, 40, |r| r.range_u64(1, 1000) as u32);
        lens.sort_unstable_by(|a, b| b.cmp(a)); // descending
        let pairs: Vec<(u32, usize)> = lens.iter().copied().zip(0..).collect();
        let cap = rng.range_u64(0, 1200) as u32;
        let got = best_fit_leq(&pairs, cap);
        let want = pairs.iter().position(|(l, _)| *l <= cap);
        assert_eq!(got, want, "cap={cap} lens={lens:?}");
    });
}

// ---------------------------------------------------------------------
// End-to-end scheduler invariants on random workloads
// ---------------------------------------------------------------------

fn random_items(rng: &mut Rng, n: usize, max_len: u32) -> Vec<TraceItem> {
    let mut t = 0.0;
    (0..n)
        .map(|_| {
            t += rng.exponential(5.0);
            let prompt_len = 1 + sized(rng, (max_len / 3) as usize) as u32;
            let true_rl =
                1 + sized(rng, (max_len - prompt_len).min(300) as usize) as u32;
            TraceItem { arrival: t, prompt_len, true_rl }
        })
        .collect()
}

fn mini_cfg(kvc_tokens: u64) -> SystemConfig {
    let mut profile = ModelProfile::opt_13b();
    profile.kvc_bytes = 819_200 * kvc_tokens;
    profile.max_total_len = 1024;
    let mut cfg = SystemConfig::new(profile);
    cfg.t_p = 0.05;
    cfg.t_g = 0.022;
    cfg
}

#[test]
fn every_scheduler_conserves_and_completes() {
    run_prop("sched_conservation", 20, |rng| {
        let n = 12 + sized(rng, 30);
        let items = random_items(rng, n, 900);
        let systems = econoserve::sched::all_systems();
        let sys = systems[rng.range_usize(0, systems.len() - 1)];
        let cfg = mini_cfg(4096);
        let pred = Box::new(SimPredictor::new(0.15, cfg.block_size, rng.next_u64()));
        let mut world = econoserve::core::world::World::new(cfg, &items, pred);
        let mut sched = econoserve::sched::by_name(sys).unwrap();
        let engine = SimEngine::new();
        let res = run(&mut world, sched.as_mut(), &engine, RunLimits::default());
        assert_eq!(res.summary.n_done, items.len(), "{sys} lost requests");
        // Conservation: exact token counts, KVC fully returned.
        for rec in &world.recs {
            assert_eq!(rec.generated, rec.req.true_rl, "{sys}: wrong token count");
            assert_eq!(rec.prompt_done, rec.req.prompt_len);
            assert!(rec.done_at.unwrap() >= rec.req.arrival);
        }
        assert_eq!(world.pool.total_allocated(), 0, "{sys} leaked KVC");
        world.pool.check_invariants();
        world.pipes.check_invariants();
        assert_eq!(world.pipes.guest_count(), 0);
    });
}

#[test]
fn econoserve_oracle_never_evicts_guests() {
    run_prop("oracle_no_evictions", 15, |rng| {
        let n = 20 + sized(rng, 25);
        let items = random_items(rng, n, 700);
        let mut cfg = mini_cfg(3000);
        cfg.padding_ratio = 0.10;
        let pred = Box::new(OraclePredictor::new(cfg.block_size));
        let mut world = econoserve::core::world::World::new(cfg, &items, pred);
        let mut sched = econoserve::sched::by_name("econoserve").unwrap();
        let engine = SimEngine::new();
        let res = run(&mut world, sched.as_mut(), &engine, RunLimits::default());
        assert_eq!(res.summary.n_done, items.len());
        // Exact predictions + buffer: the Fig 7 invariant means a hosted
        // GT always completes before its host's write head arrives.
        assert_eq!(world.col.pipeline_evictions, 0, "guest evicted under oracle predictions");
    });
}

#[test]
fn exact_allocation_never_fails_for_multires() {
    run_prop("multires_no_fail", 15, |rng| {
        let n = 15 + sized(rng, 25);
        let items = random_items(rng, n, 700);
        let cfg = mini_cfg(4096);
        let pred = Box::new(OraclePredictor::new(cfg.block_size));
        let mut world = econoserve::core::world::World::new(cfg, &items, pred);
        let mut sched = econoserve::sched::by_name("multires").unwrap();
        let engine = SimEngine::new();
        let res = run(&mut world, sched.as_mut(), &engine, RunLimits::default());
        assert_eq!(res.summary.n_done, items.len());
        assert_eq!(world.pool.alloc_failures, 0);
    });
}

#[test]
fn deterministic_given_seed() {
    run_prop("determinism", 8, |rng| {
        let seed = rng.next_u64();
        let go = || {
            let mut r = Rng::new(seed);
            let items = random_items(&mut r, 25, 800);
            let mut cfg = mini_cfg(4096);
            // Scheduling time is measured wall-clock; charge none so the
            // simulated clock is bit-deterministic for this test.
            cfg.sched_time_scale = 0.0;
            let pred = Box::new(SimPredictor::new(0.15, cfg.block_size, seed));
            let mut world = econoserve::core::world::World::new(cfg, &items, pred);
            let mut sched = econoserve::sched::by_name("econoserve").unwrap();
            let engine = SimEngine::new();
            let res = run(&mut world, sched.as_mut(), &engine, RunLimits::default());
            (res.summary.n_done, res.summary.iterations, format!("{:.9}", res.summary.mean_jct))
        };
        let a = go();
        let b = go();
        assert_eq!(a, b, "simulation must be bit-deterministic at sched_time_scale=0");
    });
}
