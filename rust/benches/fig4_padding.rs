//! Paper figure driver: see econoserve::figures::fig4.
//! Run with `cargo bench --bench fig4_padding` (add FAST=1 for a quick pass).
fn main() {
    let fast = std::env::var("FAST").is_ok();
    econoserve::figures::fig4::run(fast);
}
