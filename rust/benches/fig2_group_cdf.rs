//! Paper figure driver: see econoserve::figures::fig2.
//! Run with `cargo bench --bench fig2_group_cdf` (add FAST=1 for a quick pass).
fn main() {
    let fast = std::env::var("FAST").is_ok();
    econoserve::figures::fig2::run_fig(fast);
}
