//! Paper figure driver: see econoserve::figures::fig12.
//! Run with `cargo bench --bench fig12_gpu_count` (add FAST=1 for a quick pass).
fn main() {
    let fast = std::env::var("FAST").is_ok();
    econoserve::figures::fig12::run(fast);
}
