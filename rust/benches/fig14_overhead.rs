//! Paper figure driver: see econoserve::figures::fig14.
//! Run with `cargo bench --bench fig14_overhead` (add FAST=1 for a quick pass).
fn main() {
    let fast = std::env::var("FAST").is_ok();
    econoserve::figures::fig14::run(fast);
}
