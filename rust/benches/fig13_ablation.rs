//! Paper figure driver: see econoserve::figures::fig13.
//! Run with `cargo bench --bench fig13_ablation` (add FAST=1 for a quick pass).
fn main() {
    let fast = std::env::var("FAST").is_ok();
    econoserve::figures::fig13::run(fast);
}
