//! Paper figure driver: see econoserve::figures::fig6.
//! Run with `cargo bench --bench fig6_occupied_kvc` (add FAST=1 for a quick pass).
fn main() {
    let fast = std::env::var("FAST").is_ok();
    econoserve::figures::fig6::run(fast);
}
