//! Paper figure driver: see econoserve::figures::fig5.
//! Run with `cargo bench --bench fig5_misprediction` (add FAST=1 for a quick pass).
fn main() {
    let fast = std::env::var("FAST").is_ok();
    econoserve::figures::fig5::run(fast);
}
