//! Paper figure driver: see econoserve::figures::fig15.
//! Run with `cargo bench --bench fig15_sensitivity` (add FAST=1 for a quick pass).
fn main() {
    let fast = std::env::var("FAST").is_ok();
    econoserve::figures::fig15::run(fast);
}
