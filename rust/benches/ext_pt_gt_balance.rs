//! EXTENSION (the paper's §6 future work): "whether EconoServe would lead
//! to imbalances in processing speeds of PTs and GTs, and how it affects
//! the performance."
//!
//! We measure, per trace and load level, the PT-side and GT-side token
//! processing rates, the idle prompt-KV share, and the resulting JCT —
//! quantifying the imbalance the decoupled design can create and how the
//! PT-intake gate (`gt_stage_frac`) trades it off.

use econoserve::figures::common;
use econoserve::util::bench::BenchOut;
use econoserve::util::stats::Table;

fn main() {
    let mut out = BenchOut::new("ext_pt_gt_balance");
    let fast = std::env::var("FAST").is_ok();
    let duration = if fast { 20.0 } else { 60.0 };

    for trace in ["alpaca", "sharegpt"] {
        let mut t = Table::new(&[
            "load_x",
            "stage_frac",
            "pt_tok_rate",
            "gt_tok_rate",
            "waiting_kv_%",
            "jct_s",
            "tput_rps",
        ]);
        for load in [0.6, 1.0, 1.4] {
            for stage in [0.02, 0.05, 0.15] {
                let mut cfg = common::cfg("opt-13b", trace);
                cfg.gt_stage_frac = stage;
                let rate = common::capacity_estimate(&cfg, trace) * load;
                let items = common::workload(&cfg, trace, rate, duration, cfg.seed);
                let (res, world) =
                    common::run_world(&cfg, "econoserve", trace, &items, false, 1200.0);
                let span = res.end_time.max(1e-9);
                let pt_tokens: u64 =
                    world.recs.iter().map(|r| r.prompt_done as u64).sum();
                let gt_tokens: u64 = world.recs.iter().map(|r| r.generated as u64).sum();
                t.rowf(
                    &format!("{load}@{stage}"),
                    &[
                        stage,
                        pt_tokens as f64 / span,
                        gt_tokens as f64 / span,
                        world.col.brk_waiting_held.mean() * 100.0,
                        res.summary.mean_jct,
                        res.summary.throughput_rps,
                    ],
                );
            }
        }
        out.section(&format!("{trace}: PT/GT processing balance"), t);
    }
    out.finish();
}
