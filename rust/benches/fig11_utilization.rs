//! Paper figure driver: see econoserve::figures::fig11.
//! Run with `cargo bench --bench fig11_utilization` (add FAST=1 for a quick pass).
fn main() {
    let fast = std::env::var("FAST").is_ok();
    econoserve::figures::fig11::run(fast);
}
