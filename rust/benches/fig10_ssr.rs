//! Paper figure driver: see econoserve::figures::fig10.
//! Run with `cargo bench --bench fig10_ssr` (add FAST=1 for a quick pass).
fn main() {
    let fast = std::env::var("FAST").is_ok();
    econoserve::figures::fig10::run(fast);
}
