//! Paper figure driver: see econoserve::figures::fig1.
//! Run with `cargo bench --bench fig1_schedulers` (add FAST=1 for a quick pass).
fn main() {
    let fast = std::env::var("FAST").is_ok();
    econoserve::figures::fig1::run(fast);
}
