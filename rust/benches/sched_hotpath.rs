//! Micro-benchmark: batch-formation (`Scheduler::plan`) latency per
//! sched × alloc combination across queue depths — backs Fig 14 and the
//! §Perf L3 target (<= 50 µs at 1k-deep queues for EconoServe).
//!
//! Sweeps 100 / 1 000 / 10 000 queued requests so the indexed hot path's
//! scaling is visible, not just its constant factor. Run directly for the
//! human-readable table, or with `--json <path>` (what `scripts/bench.sh`
//! does) to emit a single machine-readable `BENCH_sched.json` with
//! p50/p95 per (combo, depth) so the perf trajectory is tracked across
//! PRs and gated in CI (`scripts/bench_gate.py`).
//!
//! Modes: `FAST=1` benches default pairings at the 1k depth only plus
//! one `fleet_routing` case (the CI short mode); the full run covers the
//! supported grid at every depth and the whole fleet router axis.
//! Every default pairing also gets a `+trace` row at the headline depth
//! (FAST mode included): the same plan+apply loop with the span-trace
//! recorder enabled, so tracing's hot-path overhead is a tracked,
//! gateable number rather than folklore
//! (`fleet_routing+<router>`: per-arrival snapshot+route cost of the
//! fleet front door over a 4-replica fleet; `+chaos` variants route the
//! same fleet with half the replicas marked unhealthy, the health-aware
//! filter path fault injection exercises; `+guardrails` variants stack
//! the brownout controller's pressure computation and admission check on
//! top of the route).
//!
//! The combo grid itself runs on the parallel experiment engine
//! (`econoserve::exp::map_indexed`): pass `--threads N` (0 = auto) to
//! fan the independent (combo, depth) cells out. The default stays
//! `--threads 1` because per-sample latencies measured with neighbours
//! in flight are contention-noisy — commit gate baselines from
//! single-thread runs; use multi-thread sweeps for quick coverage. The
//! JSON artifact records both knobs (`sweep_threads`, `sweep_wall_s`),
//! so single- vs multi-thread sweep wall-clock is tracked per run.

use econoserve::coordinator::Stepper;
use econoserve::core::world::World;
use econoserve::engine::{Engine, SimEngine};
use econoserve::figures::common;
use econoserve::fleet::router::{self, ReplicaSnapshot};
use econoserve::sched::plan_iteration;
use econoserve::telemetry::TraceConfig;
use econoserve::util::bench::{black_box, time_fn};
use econoserve::util::rng::{derive_seed, stream};
use std::time::{Duration, Instant};

const SCHEDS: [&str; 7] =
    ["orca", "fastserve", "vllm", "sarathi", "multires", "sync_coupled", "econoserve"];

/// Queue depths swept (queued requests at bench start).
const DEPTHS: [usize; 3] = [100, 1_000, 10_000];
/// The depth used for the headline table and the FAST/CI mode.
const HEADLINE_DEPTH: usize = 1_000;

/// Allocators a scheduler can run under sustained overload. Schedulers
/// without mid-flight lease growth or a preemption recovery path (the
/// ORCA family; the exact-allocation group for `block`) need an
/// admission-complete allocator — those combos are excluded, and the
/// exclusion is printed rather than silently skipped.
fn allocs_for(sched: &str) -> &'static [&'static str] {
    match sched {
        "orca" | "fastserve" => &["max", "pipelined-max"],
        "vllm" | "sarathi" => &["block", "exact", "pipelined-block", "pipelined-exact"],
        _ => &["exact", "pipelined-exact", "max"],
    }
}

struct Row {
    combo: String,
    depth: usize,
    mean_s: f64,
    p50_s: f64,
    p95_s: f64,
    samples: usize,
}

/// One grid cell: either a sched+alloc plan-latency case or a fleet
/// front-door routing case (`guardrails` adds the brownout pressure
/// computation + admission check the reliability layer runs per event).
enum Task {
    Combo { combo: String, depth: usize, trace: bool },
    Routing { router: &'static str, depth: usize, chaos: bool, guardrails: bool },
}

fn bench_combo(combo: &str, depth: usize, trace: bool, fast: bool) -> (Row, String) {
    let cfg = common::cfg("opt-13b", "sharegpt");
    // Build a world mid-overload: `depth` queued requests.
    let items = common::workload(&cfg, "sharegpt", depth as f64 / 2.0, 2.0, 7);
    let pred = Box::new(econoserve::predictor::SimPredictor::for_trace(
        "sharegpt",
        cfg.block_size,
        cfg.seed,
    ));
    let trace_seed = derive_seed(cfg.seed, stream::TRACE);
    let mut world = World::new(cfg, &items, pred);
    let sys = econoserve::sched::by_name(combo).unwrap();
    world.set_allocator(sys.alloc);
    if trace {
        // Full sampling: the worst-case per-iteration recording cost.
        world.enable_tracing(TraceConfig::new(trace_seed), 0, combo);
    }
    let mut sched = sys.sched;
    world.clock = 2.0;
    world.drain_arrivals();
    let engine = SimEngine::new();
    // Warm the system into steady state: run some iterations.
    for _ in 0..50 {
        let b = plan_iteration(&mut world, sched.as_mut());
        if b.is_empty() {
            world.clock += 0.05;
            continue;
        }
        let (d, u) = engine.iteration_cost(&b, &world);
        world.apply_plan(&b, d, u);
        world.recycle_plan(b);
    }
    let (min_iters, min_time) = if fast {
        (50, Duration::from_millis(75))
    } else {
        (100, Duration::from_millis(150))
    };
    let mut res = time_fn(
        || {
            let b = plan_iteration(&mut world, sched.as_mut());
            if !b.is_empty() {
                let (d, u) = engine.iteration_cost(&b, &world);
                world.apply_plan(&b, d, u);
            }
            world.recycle_plan(b);
            black_box(());
        },
        min_iters,
        min_time,
    );
    let name = if trace { format!("{combo}+trace") } else { combo.to_string() };
    let report = res.report(&name);
    let row = Row {
        combo: name,
        depth,
        mean_s: res.samples.mean(),
        p50_s: res.samples.p50(),
        p95_s: res.samples.p95(),
        samples: res.samples.len(),
    };
    (row, report)
}

/// Fleet front-door hot path: snapshot the routable replica set and make
/// one routing decision, against a 4-replica fleet holding `depth`
/// queued requests total. This is the per-arrival cost the fleet layer
/// adds on top of per-replica planning. With `chaos`, half the replicas
/// are snapshotted unhealthy (crashed-but-listed, as under fault
/// injection), so the routers' health-filter path is what gets timed.
/// With `guardrails`, the brownout controller's per-tick work (fleet
/// pressure over the snapshots, tier update, one admission check) is
/// timed on top of the route — the reliability layer's event overhead.
fn bench_fleet_routing(
    router_name: &str,
    depth: usize,
    chaos: bool,
    guardrails: bool,
    fast: bool,
) -> (Row, String) {
    const REPLICAS: usize = 4;
    let cfg = common::cfg("opt-13b", "sharegpt");
    let per = (depth / REPLICAS).max(1);
    let steppers: Vec<Stepper> = (0..REPLICAS)
        .map(|i| {
            let mut c = cfg.clone();
            c.seed = derive_seed(cfg.seed, stream::replica(i));
            let items = common::workload(&c, "sharegpt", per as f64 / 2.0, 2.0, 7 + i as u64);
            let mut st = Stepper::new(c, "econoserve", "sharegpt", false, &items);
            st.world.clock = 2.0;
            st.world.drain_arrivals();
            st
        })
        .collect();
    let mut rt = router::by_name(router_name, derive_seed(cfg.seed, stream::ROUTER)).unwrap();
    let mut snaps: Vec<ReplicaSnapshot> = Vec::with_capacity(REPLICAS);
    let gcfg = econoserve::reliability::GuardrailConfig::parse("full").unwrap();
    let mut brownout = econoserve::reliability::Brownout::new(&gcfg);
    // Matches `FleetConfig::knobs` for the sharegpt mix closely enough
    // for a latency bench; the value only shapes the pressure ratio.
    let resident_ceiling = 40.0;
    let (min_iters, min_time) = if fast {
        (1_000, Duration::from_millis(75))
    } else {
        (2_000, Duration::from_millis(150))
    };
    let mut res = time_fn(
        || {
            snaps.clear();
            for (id, st) in steppers.iter().enumerate() {
                let healthy = !chaos || id % 2 == 0;
                snaps.push(ReplicaSnapshot::of_world(id, &st.world, healthy));
            }
            if guardrails {
                let p = econoserve::reliability::fleet_pressure(&snaps, resident_ceiling);
                brownout.update(p);
                black_box(brownout.admits(512));
            }
            black_box(rt.route(&snaps));
        },
        min_iters,
        min_time,
    );
    let suffix = match (chaos, guardrails) {
        (true, true) => "+chaos+guardrails",
        (true, false) => "+chaos",
        (false, true) => "+guardrails",
        (false, false) => "",
    };
    let combo = format!("fleet_routing+{router_name}{suffix}");
    let report = res.report(&combo);
    let row = Row {
        combo,
        depth,
        mean_s: res.samples.mean(),
        p50_s: res.samples.p50(),
        p95_s: res.samples.p95(),
        samples: res.samples.len(),
    };
    (row, report)
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let flag = |name: &str| {
        args.iter().position(|a| a == name).and_then(|i| args.get(i + 1)).cloned()
    };
    let json_path = flag("--json");
    let threads: usize = flag("--threads")
        .map(|v| v.parse().expect("--threads must be an integer (0 = auto)"))
        .unwrap_or(1);
    let fast = std::env::var("FAST").is_ok();

    let depths: &[usize] = if fast { &[HEADLINE_DEPTH] } else { &DEPTHS };
    println!(
        "scheduler plan latency (sharegpt, opt-13b), sched x alloc grid, depths {depths:?}:"
    );

    // The grid, in deterministic order (skips are reported up front so
    // the parallel sweep only carries real cells).
    let mut tasks: Vec<Task> = Vec::new();
    for sched in SCHEDS {
        // Default pairing first, then the rest of the supported axis.
        let default = econoserve::sched::default_alloc(sched).unwrap();
        for &depth in depths {
            tasks.push(Task::Combo {
                combo: format!("{sched}+{default}"),
                depth,
                trace: false,
            });
        }
        // Trace-on twin of the default pairing at the headline depth
        // (FAST included): trace-off vs trace-on is the recorder's
        // hot-path overhead.
        tasks.push(Task::Combo {
            combo: format!("{sched}+{default}"),
            depth: HEADLINE_DEPTH,
            trace: true,
        });
        if fast {
            continue;
        }
        let supported = allocs_for(sched);
        for alloc in econoserve::kvc::all_allocators() {
            if *alloc == default {
                continue;
            }
            if supported.contains(alloc) {
                // Non-default pairings: headline depth only (the grid is
                // about coverage; the scaling sweep rides the defaults).
                tasks.push(Task::Combo {
                    combo: format!("{sched}+{alloc}"),
                    depth: HEADLINE_DEPTH,
                    trace: false,
                });
            } else {
                println!("  {sched}+{alloc}: skipped (needs admission-complete lease)");
            }
        }
    }
    // Fleet front-door routing: one representative router in the
    // FAST/CI set, the full router axis in the long run.
    let routers: &[&str] = if fast {
        &["least-kvc"]
    } else {
        &["round-robin", "least-queue", "least-kvc", "power-of-two"]
    };
    for r in routers {
        tasks.push(Task::Routing {
            router: r,
            depth: HEADLINE_DEPTH,
            chaos: false,
            guardrails: false,
        });
        tasks.push(Task::Routing { router: r, depth: HEADLINE_DEPTH, chaos: true, guardrails: false });
        tasks.push(Task::Routing {
            router: r,
            depth: HEADLINE_DEPTH,
            chaos: false,
            guardrails: true,
        });
    }

    let sweep_threads = econoserve::exp::resolve_threads(threads);
    if sweep_threads > 1 {
        println!(
            "  (sweep on {sweep_threads} threads: wall-clock mode; per-sample latencies \
             are contention-noisy — commit gate baselines from --threads 1 runs)"
        );
    }
    let t0 = Instant::now();
    let results: Vec<(Row, String)> =
        econoserve::exp::map_indexed(&tasks, sweep_threads, |_, task| match task {
            Task::Combo { combo, depth, trace } => bench_combo(combo, *depth, *trace, fast),
            Task::Routing { router, depth, chaos, guardrails } => {
                bench_fleet_routing(router, *depth, *chaos, *guardrails, fast)
            }
        });
    let sweep_wall_s = t0.elapsed().as_secs_f64();
    for (row, report) in &results {
        println!("  [depth {:>5}] {report}", row.depth);
    }
    println!("sweep wall-clock: {sweep_wall_s:.2}s on {sweep_threads} thread(s)");
    let rows: Vec<Row> = results.into_iter().map(|(r, _)| r).collect();

    if let Some(path) = json_path {
        // Machine label for the regression gate: p50s are only comparable
        // on like hardware, so scripts/bench_gate.py fails on a regression
        // only when the hosts match (CI pins BENCH_HOST to its runner
        // flavor; scripts/bench.sh defaults it to `uname -sm`).
        let host = std::env::var("BENCH_HOST").unwrap_or_else(|_| "unknown".to_string());
        let mut out = String::from("{\n");
        out.push_str("  \"bench\": \"sched_hotpath\",\n");
        out.push_str(&format!("  \"host\": \"{host}\",\n"));
        out.push_str("  \"unit\": \"seconds_per_iteration\",\n");
        out.push_str(&format!(
            "  \"workload\": \"sharegpt opt-13b, queue-depth sweep {DEPTHS:?} (FAST: {HEADLINE_DEPTH} only)\",\n"
        ));
        out.push_str("  \"note\": \"plan-formation latency per sched+alloc combo and queue depth; regenerate with scripts/bench.sh, gate with scripts/bench_gate.py; sweep_threads/sweep_wall_s track the grid's own wall-clock (exp::map_indexed fan-out)\",\n");
        out.push_str(&format!("  \"sweep_threads\": {sweep_threads},\n"));
        out.push_str(&format!("  \"sweep_wall_s\": {sweep_wall_s:.3},\n"));
        out.push_str("  \"combos\": [\n");
        for (i, r) in rows.iter().enumerate() {
            out.push_str(&format!(
                "    {{\"system\": \"{}\", \"depth\": {}, \"mean\": {:.9}, \"p50\": {:.9}, \"p95\": {:.9}, \"samples\": {}}}{}\n",
                r.combo,
                r.depth,
                r.mean_s,
                r.p50_s,
                r.p95_s,
                r.samples,
                if i + 1 < rows.len() { "," } else { "" }
            ));
        }
        out.push_str("  ]\n}\n");
        std::fs::write(&path, out).expect("write bench json");
        println!("wrote {path}");
    }
}
