//! Micro-benchmark: batch-formation (Scheduler::step) latency per system
//! at a deep queue — backs Fig 14 and the §Perf L3 target (<= 50 µs at
//! 1k-deep queues for EconoServe).
use econoserve::core::world::World;
use econoserve::engine::{Engine, SimEngine};
use econoserve::figures::common;
use econoserve::util::bench::{black_box, time_fn};
use std::time::Duration;

fn main() {
    let cfg = common::cfg("opt-13b", "sharegpt");
    println!("scheduler step latency at ~1k-deep queue (sharegpt, opt-13b):");
    for sys in ["orca", "fastserve", "vllm", "sarathi", "multires", "sync_coupled", "econoserve"] {
        // Build a world mid-overload: 1000 queued requests.
        let items = common::workload(&cfg, "sharegpt", 1000.0, 1.0, 7);
        let pred = common_pred(&cfg);
        let mut world = World::new(cfg.clone(), &items, pred);
        world.clock = 2.0;
        world.drain_arrivals();
        let mut sched = econoserve::sched::by_name(sys).unwrap();
        let engine = SimEngine::new();
        // Warm the system into steady state: run some iterations.
        for _ in 0..50 {
            let b = sched.step(&mut world);
            if b.is_empty() {
                world.clock += 0.05;
                continue;
            }
            let (d, u) = engine.iteration_cost(&b, &world);
            world.execute_iteration(&b, d, u);
        }
        let mut res = time_fn(
            || {
                let b = sched.step(&mut world);
                if !b.is_empty() {
                    let (d, u) = engine.iteration_cost(&b, &world);
                    world.execute_iteration(&b, d, u);
                }
                black_box(());
            },
            200,
            Duration::from_millis(300),
        );
        println!("  {}", res.report(sys));
    }
}

fn common_pred(
    cfg: &econoserve::config::SystemConfig,
) -> Box<dyn econoserve::predictor::Predictor> {
    Box::new(econoserve::predictor::SimPredictor::for_trace(
        "sharegpt",
        cfg.block_size,
        cfg.seed,
    ))
}
