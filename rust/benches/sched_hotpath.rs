//! Micro-benchmark: batch-formation (`Scheduler::plan`) latency per
//! sched × alloc combination at a deep queue — backs Fig 14 and the §Perf
//! L3 target (<= 50 µs at 1k-deep queues for EconoServe).
//!
//! Run directly for the human-readable table, or with
//! `--json <path>` (what `scripts/bench.sh` does) to also emit a single
//! machine-readable `BENCH_sched.json` with p50/p95 per combination so
//! the hot-path perf trajectory is tracked across PRs.

use econoserve::core::world::World;
use econoserve::engine::{Engine, SimEngine};
use econoserve::figures::common;
use econoserve::sched::plan_iteration;
use econoserve::util::bench::{black_box, time_fn};
use std::time::Duration;

const SCHEDS: [&str; 7] =
    ["orca", "fastserve", "vllm", "sarathi", "multires", "sync_coupled", "econoserve"];

/// Allocators a scheduler can run under sustained overload. Schedulers
/// without mid-flight lease growth or a preemption recovery path (the
/// ORCA family; the exact-allocation group for `block`) need an
/// admission-complete allocator — those combos are excluded, and the
/// exclusion is printed rather than silently skipped.
fn allocs_for(sched: &str) -> &'static [&'static str] {
    match sched {
        "orca" | "fastserve" => &["max", "pipelined-max"],
        "vllm" | "sarathi" => &["block", "exact", "pipelined-block", "pipelined-exact"],
        _ => &["exact", "pipelined-exact", "max"],
    }
}

struct Row {
    combo: String,
    mean_s: f64,
    p50_s: f64,
    p95_s: f64,
    samples: usize,
}

fn bench_combo(combo: &str) -> Row {
    let cfg = common::cfg("opt-13b", "sharegpt");
    // Build a world mid-overload: 1000 queued requests.
    let items = common::workload(&cfg, "sharegpt", 1000.0, 1.0, 7);
    let pred = Box::new(econoserve::predictor::SimPredictor::for_trace(
        "sharegpt",
        cfg.block_size,
        cfg.seed,
    ));
    let mut world = World::new(cfg, &items, pred);
    let sys = econoserve::sched::by_name(combo).unwrap();
    world.set_allocator(sys.alloc);
    let mut sched = sys.sched;
    world.clock = 2.0;
    world.drain_arrivals();
    let engine = SimEngine::new();
    // Warm the system into steady state: run some iterations.
    for _ in 0..50 {
        let b = plan_iteration(&mut world, sched.as_mut());
        if b.is_empty() {
            world.clock += 0.05;
            continue;
        }
        let (d, u) = engine.iteration_cost(&b, &world);
        world.apply_plan(&b, d, u);
    }
    let mut res = time_fn(
        || {
            let b = plan_iteration(&mut world, sched.as_mut());
            if !b.is_empty() {
                let (d, u) = engine.iteration_cost(&b, &world);
                world.apply_plan(&b, d, u);
            }
            black_box(());
        },
        100,
        Duration::from_millis(150),
    );
    println!("  {}", res.report(combo));
    Row {
        combo: combo.to_string(),
        mean_s: res.samples.mean(),
        p50_s: res.samples.p50(),
        p95_s: res.samples.p95(),
        samples: res.samples.len(),
    }
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .cloned();
    let fast = std::env::var("FAST").is_ok();

    println!("scheduler plan latency at ~1k-deep queue (sharegpt, opt-13b), sched x alloc grid:");
    let mut rows: Vec<Row> = Vec::new();
    for sched in SCHEDS {
        // Default pairing first, then the rest of the supported axis.
        let default = econoserve::sched::default_alloc(sched).unwrap();
        rows.push(bench_combo(&format!("{sched}+{default}")));
        if fast {
            continue;
        }
        let supported = allocs_for(sched);
        for alloc in econoserve::kvc::all_allocators() {
            if *alloc == default {
                continue;
            }
            if supported.contains(alloc) {
                rows.push(bench_combo(&format!("{sched}+{alloc}")));
            } else {
                println!("  {sched}+{alloc}: skipped (needs admission-complete lease)");
            }
        }
    }

    if let Some(path) = json_path {
        let mut out = String::from("{\n");
        out.push_str("  \"bench\": \"sched_hotpath\",\n");
        out.push_str("  \"unit\": \"seconds_per_iteration\",\n");
        out.push_str("  \"workload\": \"sharegpt opt-13b, 1000 queued requests\",\n");
        out.push_str("  \"note\": \"plan-formation latency per sched+alloc combo; regenerate with scripts/bench.sh\",\n");
        out.push_str("  \"pending\": false,\n");
        out.push_str("  \"combos\": [\n");
        for (i, r) in rows.iter().enumerate() {
            out.push_str(&format!(
                "    {{\"system\": \"{}\", \"mean\": {:.9}, \"p50\": {:.9}, \"p95\": {:.9}, \"samples\": {}}}{}\n",
                r.combo,
                r.mean_s,
                r.p50_s,
                r.p95_s,
                r.samples,
                if i + 1 < rows.len() { "," } else { "" }
            ));
        }
        out.push_str("  ]\n}\n");
        std::fs::write(&path, out).expect("write bench json");
        println!("wrote {path}");
    }
}
