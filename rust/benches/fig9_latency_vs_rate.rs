//! Paper figure driver: see econoserve::figures::fig9.
//! Run with `cargo bench --bench fig9_latency_vs_rate` (add FAST=1 for a quick pass).
fn main() {
    let fast = std::env::var("FAST").is_ok();
    econoserve::figures::fig9::run(fast);
}
