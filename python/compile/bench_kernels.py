"""L1 kernel micro-bench + structural report (EXPERIMENTS.md §Perf L1).

Usage:  cd python && python -m compile.bench_kernels

IMPORTANT: the Pallas kernels run under interpret=True here (the CPU PJRT
backend cannot execute Mosaic custom-calls), so the wallclock numbers are
NOT a TPU proxy — they only quantify the CPU-serving cost of the faithful
artifacts vs the numerically-pinned fused-jnp path (aot.py --attention).
The structural section (VMEM residency / MXU alignment) is what argues
real-TPU viability.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import attention as A
from .kernels import ref as R

jax.config.update("jax_platform_name", "cpu")


def timeit(fn, *args, iters=10):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else jax.block_until_ready(
        fn(*args)
    )
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def main() -> None:
    rng = np.random.default_rng(0)
    print("== structural report (real-TPU viability) ==")
    for b, h, p, d, t in [(8, 4, 64, 64, 160), (8, 32, 2048, 128, 4096)]:
        rep = A.vmem_report(b=b, h=h, p=p, d=d, t=t)
        print(
            f"  B={b} H={h} P={p} D={d} T={t}: decode {rep['decode_bytes_per_program']/1024:.0f} KiB"
            f" / prefill {rep['prefill_bytes_per_program']/1024:.0f} KiB per program"
            f" (budget {rep['vmem_budget_bytes']//(1024*1024)} MiB;"
            f" programs {rep['decode_programs']}/{rep['prefill_programs']})"
        )

    print("\n== CPU wallclock: interpret-mode pallas vs fused jnp oracle ==")
    shapes = [(8, 4, 160, 64), (8, 8, 512, 64)]
    for b, h, t, d in shapes:
        q = jnp.asarray(rng.standard_normal((b, h, d)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((b, h, t, d)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((b, h, t, d)), jnp.float32)
        lens = jnp.asarray(rng.integers(1, t, size=(b,)), jnp.int32)
        tp = timeit(jax.jit(lambda *a: A.decode_attention(*a)), q, k, v, lens)
        tr = timeit(jax.jit(lambda *a: R.ref_decode_attention(*a)), q, k, v, lens)
        print(
            f"  decode B={b} H={h} T={t} D={d}: pallas(interpret) {tp*1e3:8.2f} ms"
            f" | jnp-ref {tr*1e3:8.2f} ms | ratio {tp/tr:6.1f}x"
        )


if __name__ == "__main__":
    main()
