"""AOT compiler: lower the L2 model to HLO-text artifacts for the rust runtime.

Interchange format is HLO *text*, NOT ``lowered.compile().serialize()`` —
jax >= 0.5 emits HloModuleProto with 64-bit instruction ids, which the
``xla`` crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``).
The HLO text parser reassigns ids, so text round-trips cleanly (see
/opt/xla-example/gen_hlo.py).

Outputs (under --out-dir, default ../artifacts):

  manifest.json     — model config, parameter table (name/shape/offset),
                      artifact descriptions with exact input/output orders.
  weights.bin       — all parameters as little-endian f32, concatenated in
                      manifest order.
  prefill.hlo.txt   — prefill(params..., tokens[1,P], lens[1])
                      -> (logits[1,V], k[L,1,H,T,hd], v[L,1,H,T,hd])
  decode.hlo.txt    — decode_step(params..., k, v, lens[B], tokens[B])
                      -> (logits[B,V], k, v)
  insert.hlo.txt    — insert_slot(k, v, k_new, v_new, slot)
                      -> (k, v)
  golden.json       — a deterministic prompt + the greedy tokens the
                      python stack produces; rust integration tests replay
                      it through the artifacts and compare.

Params are passed as a *tuple of leaves* (not a dict) so the HLO parameter
order is exactly the manifest order, independent of pytree key sorting.
"""

from __future__ import annotations

import argparse
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    # return_tuple=False: every program here has a SINGLE array output, so
    # the HLO root is that array and PJRT returns one plain (non-tuple)
    # buffer — the property the rust runtime's on-device chaining needs.
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    return comp.as_hlo_text()


def lower_artifacts(cfg: M.ModelConfig, params):
    """Lower the packed-state entry points. Returns {filename: hlo_text}.

    Every program has a SINGLE array output (see model.py's packed-state
    docs): PJRT returns single-leaf buffers the rust runtime can chain on
    device without host round-trips.
    """
    names = list(params.keys())
    leaves = tuple(params[n] for n in names)
    specs = tuple(jax.ShapeDtypeStruct(l.shape, l.dtype) for l in leaves)

    def prefill_flat(*args):
        ps = dict(zip(names, args[: len(names)]))
        tokens, lens = args[len(names) :]
        return M.prefill_packed(cfg, ps, tokens, lens)

    def decode_flat(*args):
        ps = dict(zip(names, args[: len(names)]))
        state, lens, tokens = args[len(names) :]
        return M.decode_packed(cfg, ps, state, lens, tokens)

    i32 = jnp.int32
    f32 = jnp.float32
    tok_spec = jax.ShapeDtypeStruct((1, cfg.max_prompt), i32)
    len1_spec = jax.ShapeDtypeStruct((1,), i32)
    state_1 = jax.ShapeDtypeStruct((M.state_elems(cfg, 1),), f32)
    state_b = jax.ShapeDtypeStruct((M.state_elems(cfg, cfg.decode_slots),), f32)
    lens_b = jax.ShapeDtypeStruct((cfg.decode_slots,), i32)
    toks_b = jax.ShapeDtypeStruct((cfg.decode_slots,), i32)
    slot_spec = jax.ShapeDtypeStruct((), i32)

    return {
        "prefill.hlo.txt": to_hlo_text(
            jax.jit(prefill_flat).lower(*specs, tok_spec, len1_spec)
        ),
        "decode.hlo.txt": to_hlo_text(
            jax.jit(decode_flat).lower(*specs, state_b, lens_b, toks_b)
        ),
        "insert.hlo.txt": to_hlo_text(
            jax.jit(lambda sb, s1, slot: M.insert_packed(cfg, sb, s1, slot)).lower(
                state_b, state_1, slot_spec
            )
        ),
        "logits_1.hlo.txt": to_hlo_text(
            jax.jit(lambda s: M.read_logits(cfg, s, 1)).lower(state_1)
        ),
        "logits_b.hlo.txt": to_hlo_text(
            jax.jit(lambda s: M.read_logits(cfg, s, cfg.decode_slots)).lower(state_b)
        ),
    }


def golden_prompt(cfg: M.ModelConfig, seed: int = 7, length: int | None = None):
    """Deterministic pseudo-prompt in [1, vocab) (0 is reserved for pad)."""
    length = length or min(12, cfg.max_prompt)
    rng = np.random.default_rng(seed)
    toks = rng.integers(1, cfg.vocab, size=(length,), dtype=np.int32)
    return toks


def build(preset: str, out_dir: pathlib.Path, golden_steps: int = 8) -> dict:
    cfg = M.presets()[preset]
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    out_dir.mkdir(parents=True, exist_ok=True)

    # --- weights.bin + parameter table --------------------------------
    names = list(params.keys())
    table = []
    offset = 0
    with open(out_dir / "weights.bin", "wb") as f:
        for n in names:
            arr = np.asarray(params[n], dtype=np.float32)
            f.write(arr.tobytes())  # little-endian on all supported hosts
            table.append({"name": n, "shape": list(arr.shape), "offset": offset,
                          "elems": int(arr.size)})
            offset += int(arr.size)

    # --- HLO artifacts -------------------------------------------------
    hlos = lower_artifacts(cfg, params)
    for fname, text in hlos.items():
        (out_dir / fname).write_text(text)

    # --- golden transcript ---------------------------------------------
    toks = golden_prompt(cfg)
    padded = np.zeros((1, cfg.max_prompt), np.int32)
    padded[0, : len(toks)] = toks
    lens = jnp.asarray([len(toks)], jnp.int32)
    gen = M.greedy_generate(cfg, params, jnp.asarray(padded), lens, golden_steps)
    logits, _, _ = M.prefill(cfg, params, jnp.asarray(padded), lens)
    golden = {
        "prompt": toks.tolist(),
        "prompt_len": int(len(toks)),
        "steps": golden_steps,
        "generated": np.asarray(gen)[0].tolist(),
        "prefill_logits_l2": float(jnp.sqrt(jnp.sum(logits**2))),
        "prefill_logits_first8": np.asarray(logits)[0, :8].tolist(),
    }
    (out_dir / "golden.json").write_text(json.dumps(golden, indent=1))

    manifest = {
        "preset": preset,
        "config": {
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "n_heads": cfg.n_heads,
            "n_layers": cfg.n_layers,
            "d_ff": cfg.d_ff,
            "max_seq": cfg.max_seq,
            "max_prompt": cfg.max_prompt,
            "decode_slots": cfg.decode_slots,
            "head_dim": cfg.head_dim,
            "param_count": M.param_count(cfg),
        },
        "params": table,
        "artifacts": {
            "prefill": {
                "file": "prefill.hlo.txt",
                "inputs": names + ["tokens[1,max_prompt] i32", "lens[1] i32"],
                "outputs": ["state_1 (packed kv+logits, f32)"],
            },
            "decode": {
                "file": "decode.hlo.txt",
                "inputs": names + ["state_b", "lens[slots] i32", "tokens[slots] i32"],
                "outputs": ["state_b"],
            },
            "insert": {
                "file": "insert.hlo.txt",
                "inputs": ["state_b", "state_1", "slot i32"],
                "outputs": ["state_b"],
            },
            "logits_1": {"file": "logits_1.hlo.txt", "inputs": ["state_1"], "outputs": ["logits[1,vocab]"]},
            "logits_b": {"file": "logits_b.hlo.txt", "inputs": ["state_b"], "outputs": ["logits[slots,vocab]"]},
            "state_elems_1": M.state_elems(cfg, 1),
            "state_elems_b": M.state_elems(cfg, cfg.decode_slots),
        },
    }
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=1))
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--preset", default="tiny", choices=list(M.presets()))
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--golden-steps", type=int, default=8)
    ap.add_argument(
        "--attention",
        default="pallas",
        choices=["pallas", "ref"],
        help="attention impl lowered into the artifacts (see model.ATTENTION_IMPL)",
    )
    args = ap.parse_args()
    M.ATTENTION_IMPL = args.attention
    manifest = build(args.preset, pathlib.Path(args.out_dir), args.golden_steps)
    cfgd = manifest["config"]
    print(
        f"AOT ok: preset={manifest['preset']} params={cfgd['param_count']:,} "
        f"artifacts -> {args.out_dir}"
    )


if __name__ == "__main__":
    main()
