"""Pure-jnp reference oracles for the Pallas attention kernels.

These are the ground truth the Pallas kernels (attention.py) are validated
against in python/tests/test_kernel.py. They are intentionally written in
the most direct way possible (full materialized score matrices, explicit
masking) so that they are easy to audit, even though they are memory-hungry.
"""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def ref_decode_attention(q, k, v, lens):
    """Single-token (decode-step) attention against a padded KV cache.

    Args:
      q:    [B, H, D]     query for the one new token of each sequence.
      k:    [B, H, T, D]  key cache, padded to T along the time axis.
      v:    [B, H, T, D]  value cache.
      lens: [B] int32     number of valid cache entries per sequence
                          (INCLUDING the new token, whose K/V has already
                          been written at position lens-1).

    Returns:
      out: [B, H, D] attention output. Rows with lens == 0 return zeros.
    """
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype))
    scores = jnp.einsum("bhd,bhtd->bht", q, k) * scale
    t = jnp.arange(k.shape[2])[None, None, :]
    valid = t < lens[:, None, None]
    scores = jnp.where(valid, scores, NEG_INF)
    # Safe softmax: subtract running max; fully-masked rows become uniform
    # garbage, so zero them out explicitly afterwards.
    scores = scores - jnp.max(scores, axis=-1, keepdims=True)
    w = jnp.exp(scores)
    w = jnp.where(valid, w, 0.0)
    denom = jnp.sum(w, axis=-1, keepdims=True)
    w = w / jnp.maximum(denom, 1e-30)
    out = jnp.einsum("bht,bhtd->bhd", w, v)
    alive = (lens > 0)[:, None, None]
    return jnp.where(alive, out, 0.0).astype(q.dtype)


def ref_prefill_attention(q, k, v, lens):
    """Causal self-attention over a padded prompt.

    Args:
      q, k, v: [B, H, P, D] packed projections of the padded prompt.
      lens:    [B] int32    true prompt lengths (positions >= lens are pad).

    Returns:
      out: [B, H, P, D]; rows at padded positions are zeroed.
    """
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype))
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    p = q.shape[2]
    qi = jnp.arange(p)[:, None]
    ki = jnp.arange(p)[None, :]
    causal = ki <= qi  # [P, P]
    inlen = ki < lens[:, None, None, None]  # [B,1,1,P]
    mask = causal[None, None, :, :] & inlen
    scores = jnp.where(mask, scores, NEG_INF)
    scores = scores - jnp.max(scores, axis=-1, keepdims=True)
    w = jnp.exp(scores)
    w = jnp.where(mask, w, 0.0)
    denom = jnp.sum(w, axis=-1, keepdims=True)
    w = w / jnp.maximum(denom, 1e-30)
    out = jnp.einsum("bhqk,bhkd->bhqd", w, v)
    qvalid = (jnp.arange(p)[None, None, :, None] < lens[:, None, None, None])
    return jnp.where(qvalid, out, 0.0).astype(q.dtype)
