"""Pallas attention kernels (L1) for the EconoServe serving stack.

Two kernels, both flash-attention style (online softmax, never materialize
the full score matrix):

  * ``decode_attention``  — one new query token per sequence against a
    padded KV cache. This is the per-iteration hot spot of the *generation
    tasks* (GTs) in the paper.
  * ``prefill_attention`` — causal attention over a padded prompt. This is
    the hot spot of the *prompt-processing tasks* (PTs).

TPU adaptation of the paper's GPU hot path (see DESIGN.md
§Hardware-Adaptation): instead of CUDA threadblocks staging HBM->shared
memory, the HBM->VMEM schedule is expressed through BlockSpecs (one
(batch, head) — and for prefill, query-tile — program instance per grid
step) and an inner ``fori_loop`` over KV tiles sized for VMEM residency.
Matmul shapes keep the head dim as the 128-lane minor axis so the MXU sees
well-formed (tile x D) x (D) / (tile x D) contractions; accumulation is
always f32 regardless of the input dtype.

Kernels MUST be run with ``interpret=True`` on this image: CPU PJRT cannot
execute Mosaic custom-calls. Correctness is pinned to the pure-jnp oracle
in ref.py by python/tests/test_kernel.py (hypothesis sweeps shapes/dtypes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30

# KV-tile length. 128 keeps the second-minor dimension MXU/VPU aligned and
# bounds per-step VMEM at (KV_TILE x D) x 2 (K and V) x 4B — for D=128 that
# is 128KiB, far under the ~16MiB VMEM budget, leaving room for
# double-buffering on real hardware.
KV_TILE = 128
# Query-tile length for prefill.
Q_TILE = 64


def _pad_axis(x, axis, multiple):
    """Zero-pad ``x`` along ``axis`` up to the next multiple of ``multiple``."""
    n = x.shape[axis]
    rem = (-n) % multiple
    if rem == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, rem)
    return jnp.pad(x, widths)


# ---------------------------------------------------------------------------
# Decode kernel
# ---------------------------------------------------------------------------


def _decode_kernel(lens_ref, q_ref, k_ref, v_ref, o_ref, *, kv_tiles, scale):
    """One (batch, head) program instance: q [1,1,D] vs cache [1,1,T,D]."""
    q = q_ref[0, 0, :].astype(jnp.float32)  # [D]
    seq_len = lens_ref[0]

    def body(i, carry):
        m, s, acc = carry
        start = i * KV_TILE
        k = pl.load(k_ref, (0, 0, pl.dslice(start, KV_TILE), slice(None)))
        v = pl.load(v_ref, (0, 0, pl.dslice(start, KV_TILE), slice(None)))
        k = k.astype(jnp.float32)
        v = v.astype(jnp.float32)
        scores = jnp.dot(k, q) * scale  # [KV_TILE]
        idx = start + jax.lax.iota(jnp.int32, KV_TILE)
        mask = idx < seq_len
        scores = jnp.where(mask, scores, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(scores))
        # Guard: in a fully-masked tile m_new may still be NEG_INF, and
        # exp(NEG_INF - NEG_INF) = 1 would pollute the sums. Re-mask.
        p = jnp.where(mask, jnp.exp(scores - m_new), 0.0)  # [KV_TILE]
        corr = jnp.exp(m - m_new)
        s_new = s * corr + jnp.sum(p)
        acc_new = acc * corr + jnp.dot(p, v)  # [D]
        return m_new, s_new, acc_new

    d = q_ref.shape[-1]
    m0 = jnp.float32(NEG_INF)
    s0 = jnp.float32(0.0)
    acc0 = jnp.zeros((d,), jnp.float32)
    _, s, acc = jax.lax.fori_loop(0, kv_tiles, body, (m0, s0, acc0))
    out = acc / jnp.maximum(s, 1e-30)
    out = jnp.where(seq_len > 0, out, 0.0)
    o_ref[0, 0, :] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def decode_attention(q, k, v, lens, *, interpret=True):
    """Flash-decode attention. Shapes/semantics match ref.ref_decode_attention.

    q: [B,H,D]; k,v: [B,H,T,D]; lens: [B] int32 -> out [B,H,D].
    """
    b, h, d = q.shape
    k = _pad_axis(k, 2, KV_TILE)
    v = _pad_axis(v, 2, KV_TILE)
    t = k.shape[2]
    kv_tiles = t // KV_TILE
    scale = 1.0 / float(d) ** 0.5
    kernel = functools.partial(_decode_kernel, kv_tiles=kv_tiles, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(b, h),
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (i,)),  # lens
            pl.BlockSpec((1, 1, d), lambda i, j: (i, j, 0)),  # q
            pl.BlockSpec((1, 1, t, d), lambda i, j: (i, j, 0, 0)),  # k
            pl.BlockSpec((1, 1, t, d), lambda i, j: (i, j, 0, 0)),  # v
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, d), q.dtype),
        interpret=interpret,
    )(lens.astype(jnp.int32), q, k, v)


# ---------------------------------------------------------------------------
# Prefill kernel
# ---------------------------------------------------------------------------


def _prefill_kernel(lens_ref, q_ref, k_ref, v_ref, o_ref, *, kv_tiles, scale):
    """One (batch, head, q-tile) instance: q tile [Q_TILE,D] vs cache tiles."""
    qt = pl.program_id(2)
    q = q_ref[0, 0, :, :].astype(jnp.float32)  # [Q_TILE, D]
    seq_len = lens_ref[0]
    q_idx = qt * Q_TILE + jax.lax.iota(jnp.int32, Q_TILE)  # global q rows

    def body(i, carry):
        m, s, acc = carry
        start = i * KV_TILE
        k = pl.load(k_ref, (0, 0, pl.dslice(start, KV_TILE), slice(None)))
        v = pl.load(v_ref, (0, 0, pl.dslice(start, KV_TILE), slice(None)))
        k = k.astype(jnp.float32)
        v = v.astype(jnp.float32)
        scores = jnp.dot(q, k.T) * scale  # [Q_TILE, KV_TILE]
        k_idx = start + jax.lax.iota(jnp.int32, KV_TILE)
        mask = (k_idx[None, :] <= q_idx[:, None]) & (k_idx[None, :] < seq_len)
        scores = jnp.where(mask, scores, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(scores, axis=-1))  # [Q_TILE]
        p = jnp.where(mask, jnp.exp(scores - m_new[:, None]), 0.0)
        corr = jnp.exp(m - m_new)  # [Q_TILE]
        s_new = s * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[:, None] + jnp.dot(p, v)  # [Q_TILE, D]
        return m_new, s_new, acc_new

    d = q_ref.shape[-1]
    m0 = jnp.full((Q_TILE,), NEG_INF, jnp.float32)
    s0 = jnp.zeros((Q_TILE,), jnp.float32)
    acc0 = jnp.zeros((Q_TILE, d), jnp.float32)
    # Causal structure: only KV tiles whose start <= last row of this q tile
    # can contribute. Bounding the loop count by the q-tile index skips the
    # strictly-upper-triangular tile pairs entirely (the intra-tile boundary
    # is handled by the mask), halving prefill FLOPs exactly as the paper's
    # chunked-prefill baselines do.
    tiles_needed = jnp.minimum(
        kv_tiles, ((qt + 1) * Q_TILE + KV_TILE - 1) // KV_TILE
    )
    _, s, acc = jax.lax.fori_loop(0, tiles_needed, body, (m0, s0, acc0))
    out = acc / jnp.maximum(s, 1e-30)[:, None]
    out = jnp.where((q_idx < seq_len)[:, None], out, 0.0)
    o_ref[0, 0, :, :] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def prefill_attention(q, k, v, lens, *, interpret=True):
    """Flash prefill attention. Matches ref.ref_prefill_attention.

    q,k,v: [B,H,P,D]; lens: [B] int32 -> out [B,H,P,D].
    """
    b, h, p, d = q.shape
    qp = _pad_axis(q, 2, Q_TILE)
    kp = _pad_axis(k, 2, KV_TILE)
    vp = _pad_axis(v, 2, KV_TILE)
    p_pad = qp.shape[2]
    t_pad = kp.shape[2]
    kv_tiles = t_pad // KV_TILE
    scale = 1.0 / float(d) ** 0.5
    kernel = functools.partial(_prefill_kernel, kv_tiles=kv_tiles, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(b, h, p_pad // Q_TILE),
        in_specs=[
            pl.BlockSpec((1,), lambda i, j, t: (i,)),
            pl.BlockSpec((1, 1, Q_TILE, d), lambda i, j, t: (i, j, t, 0)),
            pl.BlockSpec((1, 1, t_pad, d), lambda i, j, t: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, t_pad, d), lambda i, j, t: (i, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, Q_TILE, d), lambda i, j, t: (i, j, t, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, p_pad, d), q.dtype),
        interpret=interpret,
    )(lens.astype(jnp.int32), qp, kp, vp)
    return out[:, :, :p, :]


def vmem_report(b, h, p, d, t):
    """Estimate per-program VMEM residency (bytes, f32) for both kernels.

    Used by DESIGN.md / EXPERIMENTS.md §Perf to argue real-TPU viability:
    interpret-mode wallclock is NOT a TPU proxy, so we reason about the
    memory schedule instead.
    """
    dec = (d + 2 * KV_TILE * d + d) * 4  # q + k/v tile + acc
    pre = (Q_TILE * d + 2 * KV_TILE * d + Q_TILE * d + 3 * Q_TILE) * 4
    return {
        "decode_bytes_per_program": dec,
        "prefill_bytes_per_program": pre,
        "decode_programs": b * h,
        "prefill_programs": b * h * ((p + Q_TILE - 1) // Q_TILE),
        "vmem_budget_bytes": 16 * 1024 * 1024,
    }
