"""L2: OPT-style decoder-only transformer with an explicit KV cache.

Three jit-able entry points are AOT-lowered by aot.py into HLO-text
artifacts the rust runtime executes:

  * ``prefill``      — process a padded prompt batch, return last-token
                       logits plus freshly written KV caches.
  * ``decode_step``  — one generation iteration for a fixed-slot batch:
                       append one token per live slot, return next-token
                       logits and updated caches.
  * ``insert_slot``  — splice a prefilled (B=1) cache into one slot of the
                       decode batch cache (continuous batching: PTs become
                       GTs without any host round-trip of KV data).

The attention hot spot calls the L1 Pallas kernels (kernels/attention.py);
everything else is plain jnp so XLA fuses it. Architecture follows OPT
(pre-LN, learned positions, ReLU FFN) scaled down to serve on the CPU PJRT
backend.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .kernels import ref as kref
from .kernels.attention import decode_attention, prefill_attention

# Attention implementation used when building artifacts:
#  * "pallas" (default) — the L1 Pallas kernels under interpret=True. This
#    is the faithful three-layer stack; on a real TPU the same kernels
#    compile to Mosaic. Interpret mode lowers to sequential per-(b,h)
#    while-loops, which the CPU backend executes slowly.
#  * "ref" — the pure-jnp oracle (one fused softmax-attention einsum):
#    numerically validated against the Pallas kernels by pytest, and ~10x
#    faster under CPU PJRT. Used for the fast CPU serving artifacts
#    (aot.py --attention ref); see EXPERIMENTS.md §Perf.
ATTENTION_IMPL = "pallas"


def _decode_attn(q, k, v, lens):
    if ATTENTION_IMPL == "ref":
        return kref.ref_decode_attention(q, k, v, lens)
    return decode_attention(q, k, v, lens)


def _prefill_attn(q, k, v, lens):
    if ATTENTION_IMPL == "ref":
        return kref.ref_prefill_attention(q, k, v, lens)
    return prefill_attention(q, k, v, lens)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Hyperparameters. ``presets()`` has the shipped configurations."""

    vocab: int = 1024
    d_model: int = 256
    n_heads: int = 4
    n_layers: int = 4
    d_ff: int = 1024
    max_seq: int = 160  # KV-cache time extent (prompt + response)
    max_prompt: int = 64  # padded prompt length for the prefill artifact
    decode_slots: int = 8  # fixed batch slots for the decode artifact

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


def presets() -> dict:
    return {
        # ~3.9M params: the end-to-end real-serving demo model.
        "tiny": ModelConfig(),
        # ~0.9M params: fast CI configuration.
        "micro": ModelConfig(
            vocab=512, d_model=128, n_heads=4, n_layers=2, d_ff=512,
            max_seq=96, max_prompt=32, decode_slots=4,
        ),
    }


def init_params(cfg: ModelConfig, key):
    """Initialize parameters as a flat dict (stable iteration order).

    A flat dict keyed by name keeps the AOT manifest (weights.bin layout)
    self-describing: rust reads names/shapes from manifest.json and uploads
    one device buffer per entry, in this exact order.
    """
    n = cfg.n_layers
    keys = jax.random.split(key, 4 + 12 * n)
    ki = iter(range(len(keys)))
    s = 0.02

    def norm(shape):
        return (jax.random.normal(keys[next(ki)], shape) * s).astype(jnp.float32)

    params = {
        "embed": norm((cfg.vocab, cfg.d_model)),
        "pos_embed": norm((cfg.max_seq, cfg.d_model)),
    }
    d, f = cfg.d_model, cfg.d_ff
    for i in range(n):
        p = f"layer{i}."
        params[p + "ln1_g"] = jnp.ones((d,), jnp.float32)
        params[p + "ln1_b"] = jnp.zeros((d,), jnp.float32)
        params[p + "wq"] = norm((d, d))
        params[p + "wk"] = norm((d, d))
        params[p + "wv"] = norm((d, d))
        params[p + "wo"] = norm((d, d))
        params[p + "ln2_g"] = jnp.ones((d,), jnp.float32)
        params[p + "ln2_b"] = jnp.zeros((d,), jnp.float32)
        params[p + "w1"] = norm((d, f))
        params[p + "b1"] = jnp.zeros((f,), jnp.float32)
        params[p + "w2"] = norm((f, d))
        params[p + "b2"] = jnp.zeros((d,), jnp.float32)
    params["lnf_g"] = jnp.ones((d,), jnp.float32)
    params["lnf_b"] = jnp.zeros((d,), jnp.float32)
    params["lm_head"] = norm((d, cfg.vocab))
    return params


def param_count(cfg: ModelConfig) -> int:
    shapes = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))
    total = 0
    for leaf in jax.tree_util.tree_leaves(shapes):
        n = 1
        for s in leaf.shape:
            n *= s
        total += n
    return total


def _layer_norm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def _split_heads(x, n_heads):
    # [B, T, D] -> [B, H, T, hd]
    b, t, d = x.shape
    return x.reshape(b, t, n_heads, d // n_heads).transpose(0, 2, 1, 3)


def _merge_heads(x):
    # [B, H, T, hd] -> [B, T, D]
    b, h, t, hd = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, t, h * hd)


def empty_cache(cfg: ModelConfig, batch: int):
    """Zeroed KV caches: k, v of shape [L, B, H, max_seq, head_dim]."""
    shape = (cfg.n_layers, batch, cfg.n_heads, cfg.max_seq, cfg.head_dim)
    return jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32)


def prefill(cfg: ModelConfig, params, tokens, lens):
    """Process a padded prompt batch.

    Args:
      tokens: [B, P] int32, zero-padded prompts (P == cfg.max_prompt).
      lens:   [B] int32 true prompt lengths.

    Returns:
      logits: [B, vocab] — logits at each sequence's LAST valid position
              (the request's first generated token comes from these).
      k_cache, v_cache: [L, B, H, max_seq, hd] with positions [0, lens)
              written and the rest zero.
    """
    b, p = tokens.shape
    h = cfg.n_heads
    x = params["embed"][tokens] + params["pos_embed"][:p][None, :, :]
    ks, vs = [], []
    for i in range(cfg.n_layers):
        pre = f"layer{i}."
        y = _layer_norm(x, params[pre + "ln1_g"], params[pre + "ln1_b"])
        q = _split_heads(y @ params[pre + "wq"], h)  # [B,H,P,hd]
        k = _split_heads(y @ params[pre + "wk"], h)
        v = _split_heads(y @ params[pre + "wv"], h)
        attn = _prefill_attn(q, k, v, lens)
        x = x + _merge_heads(attn) @ params[pre + "wo"]
        y = _layer_norm(x, params[pre + "ln2_g"], params[pre + "ln2_b"])
        y = jax.nn.relu(y @ params[pre + "w1"] + params[pre + "b1"])
        x = x + y @ params[pre + "w2"] + params[pre + "b2"]
        pad_t = cfg.max_seq - p
        ks.append(jnp.pad(k, ((0, 0), (0, 0), (0, pad_t), (0, 0))))
        vs.append(jnp.pad(v, ((0, 0), (0, 0), (0, pad_t), (0, 0))))
    x = _layer_norm(x, params["lnf_g"], params["lnf_b"])
    logits_all = x @ params["lm_head"]  # [B, P, V]
    last = jnp.maximum(lens - 1, 0)
    logits = jnp.take_along_axis(logits_all, last[:, None, None], axis=1)[:, 0, :]
    # Zero cache rows beyond each sequence's length so insert_slot can
    # splice caches without leaking pad-position garbage.
    t_idx = jnp.arange(cfg.max_seq)
    valid = (t_idx[None, :] < lens[:, None])[None, :, None, :, None]
    k_cache = jnp.stack(ks) * valid
    v_cache = jnp.stack(vs) * valid
    return logits, k_cache, v_cache


def decode_step(cfg: ModelConfig, params, k_cache, v_cache, lens, tokens):
    """One generation iteration over the fixed decode slots.

    Args:
      k_cache, v_cache: [L, B, H, T, hd] current caches.
      lens:   [B] int32 — sequence length per slot BEFORE this step
              (== the position the new token's K/V is written at). 0 marks
              a dead slot: it flows through the same HLO but its cache is
              left untouched and its logits are ignored upstream.
      tokens: [B] int32 — token to feed per slot.

    Returns:
      (logits [B, vocab], k_cache, v_cache). The artifact is pure: lens are
      incremented by the rust coordinator, not here.
    """
    b = tokens.shape[0]
    h = cfg.n_heads
    pos = jnp.minimum(lens, cfg.max_seq - 1)
    x = params["embed"][tokens] + params["pos_embed"][pos]  # [B, D]
    alive_b = lens > 0  # [B] bool
    alive = alive_b[:, None].astype(jnp.float32)

    new_k, new_v = [], []
    for i in range(cfg.n_layers):
        pre = f"layer{i}."
        y = _layer_norm(x, params[pre + "ln1_g"], params[pre + "ln1_b"])
        q = (y @ params[pre + "wq"]).reshape(b, h, cfg.head_dim)
        k = (y @ params[pre + "wk"]).reshape(b, h, cfg.head_dim)
        v = (y @ params[pre + "wv"]).reshape(b, h, cfg.head_dim)
        # Write this token's K/V at position `pos`, only for live slots.
        onehot = (jnp.arange(cfg.max_seq)[None, :] == pos[:, None]) & alive_b[:, None]
        onehot = onehot.astype(jnp.float32)[:, None, :, None]  # [B,1,T,1]
        k_layer = k_cache[i] * (1.0 - onehot) + onehot * k[:, :, None, :]
        v_layer = v_cache[i] * (1.0 - onehot) + onehot * v[:, :, None, :]
        new_k.append(k_layer)
        new_v.append(v_layer)
        # Attend over lens+1 valid entries (the one just written included).
        attn = _decode_attn(q, k_layer, v_layer, lens + alive_b)
        x = x + (attn.reshape(b, -1) @ params[pre + "wo"]) * alive
        y = _layer_norm(x, params[pre + "ln2_g"], params[pre + "ln2_b"])
        y = jax.nn.relu(y @ params[pre + "w1"] + params[pre + "b1"])
        x = x + (y @ params[pre + "w2"] + params[pre + "b2"]) * alive
    x = _layer_norm(x, params["lnf_g"], params["lnf_b"])
    logits = x @ params["lm_head"]
    return logits, jnp.stack(new_k), jnp.stack(new_v)


def insert_slot(cfg: ModelConfig, k_cache, v_cache, k_new, v_new, slot):
    """Splice a prefilled B=1 cache into decode-batch slot ``slot``.

    k_cache/v_cache: [L, B, H, T, hd]; k_new/v_new: [L, 1, H, T, hd];
    slot: [] int32. Returns updated caches.
    """
    k = jax.lax.dynamic_update_slice(k_cache, k_new, (0, slot, 0, 0, 0))
    v = jax.lax.dynamic_update_slice(v_cache, v_new, (0, slot, 0, 0, 0))
    return k, v


# ---------------------------------------------------------------------------
# Convenience wrappers used by aot.py and the python tests
# ---------------------------------------------------------------------------


# ---------------------------------------------------------------------------
# Packed-state entry points (what aot.py actually lowers).
#
# PJRT (via the rust `xla` crate / xla_extension 0.5.1) returns a tuple
# root as ONE tuple buffer that cannot be split on-device, and flattens
# tuple *parameters* — so multi-output programs force a host round-trip of
# the KV caches every step. Instead every program here takes and returns a
# SINGLE flat f32 state vector:
#
#   state[b] = concat(k.ravel(), v.ravel(), logits.ravel())
#     k, v: [L, b, H, max_seq, hd]    logits: [b, vocab]
#
# so the rust runtime chains steps entirely on device and only reads the
# (tiny) logits slice back via the read_logits program.
# ---------------------------------------------------------------------------


def kv_elems(cfg: ModelConfig, batch: int) -> int:
    return cfg.n_layers * batch * cfg.n_heads * cfg.max_seq * cfg.head_dim


def state_elems(cfg: ModelConfig, batch: int) -> int:
    return 2 * kv_elems(cfg, batch) + batch * cfg.vocab


def pack_state(cfg: ModelConfig, k, v, logits):
    return jnp.concatenate([k.ravel(), v.ravel(), logits.ravel()])


def unpack_state(cfg: ModelConfig, state, batch: int):
    n = kv_elems(cfg, batch)
    shape = (cfg.n_layers, batch, cfg.n_heads, cfg.max_seq, cfg.head_dim)
    k = state[:n].reshape(shape)
    v = state[n : 2 * n].reshape(shape)
    logits = state[2 * n :].reshape(batch, cfg.vocab)
    return k, v, logits


def prefill_packed(cfg: ModelConfig, params, tokens, lens):
    """tokens [1,P], lens [1] -> state vector for a B=1 slot."""
    logits, k, v = prefill(cfg, params, tokens, lens)
    return pack_state(cfg, k, v, logits)


def decode_packed(cfg: ModelConfig, params, state, lens, tokens):
    """One decode iteration over the packed B=decode_slots state."""
    b = cfg.decode_slots
    k, v, _ = unpack_state(cfg, state, b)
    logits, k2, v2 = decode_step(cfg, params, k, v, lens, tokens)
    return pack_state(cfg, k2, v2, logits)


def insert_packed(cfg: ModelConfig, state_b, state_1, slot):
    """Splice a prefilled B=1 state into slot `slot` of the batch state.

    The batch state's logits block is preserved (the slot's first-token
    logits were already read from the B=1 state by the caller).
    """
    b = cfg.decode_slots
    kb, vb, lb = unpack_state(cfg, state_b, b)
    k1, v1, _ = unpack_state(cfg, state_1, 1)
    kb, vb = insert_slot(cfg, kb, vb, k1, v1, slot)
    return pack_state(cfg, kb, vb, lb)


def read_logits(cfg: ModelConfig, state, batch: int):
    """Extract the logits block from a packed state."""
    n = 2 * kv_elems(cfg, batch)
    return state[n:].reshape(batch, cfg.vocab)


def make_prefill_fn(cfg: ModelConfig):
    def fn(params, tokens, lens):
        return prefill(cfg, params, tokens, lens)

    return fn


def make_decode_fn(cfg: ModelConfig):
    def fn(params, k_cache, v_cache, lens, tokens):
        return decode_step(cfg, params, k_cache, v_cache, lens, tokens)

    return fn


def make_insert_fn(cfg: ModelConfig):
    def fn(k_cache, v_cache, k_new, v_new, slot):
        return insert_slot(cfg, k_cache, v_cache, k_new, v_new, slot)

    return fn


def greedy_generate(cfg: ModelConfig, params, tokens, lens, steps: int):
    """Reference autoregressive loop (python-side oracle for the rust path)."""
    logits, k, v = prefill(cfg, params, tokens, lens)
    cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out = [cur]
    cur_lens = lens
    for _ in range(steps - 1):
        logits, k, v = decode_step(cfg, params, k, v, cur_lens, cur)
        cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        cur_lens = cur_lens + 1
        out.append(cur)
    return jnp.stack(out, axis=1)  # [B, steps]
