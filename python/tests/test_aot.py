"""AOT pipeline tests: manifest/weights/golden consistency (micro preset)."""

import json
import pathlib
import struct
import tempfile

import jax
import numpy as np
import pytest

from compile import aot
from compile import model as M

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def built():
    out = pathlib.Path(tempfile.mkdtemp(prefix="econoserve_aot_"))
    manifest = aot.build("micro", out, golden_steps=4)
    return out, manifest


def test_manifest_param_table_matches_weights(built):
    out, manifest = built
    total_elems = sum(p["elems"] for p in manifest["params"])
    size = (out / "weights.bin").stat().st_size
    assert size == total_elems * 4
    assert manifest["config"]["param_count"] == total_elems
    # Offsets are contiguous.
    off = 0
    for p in manifest["params"]:
        assert p["offset"] == off
        off += p["elems"]


def test_hlo_artifacts_nonempty_and_parseable_header(built):
    out, _ = built
    for name in ["prefill.hlo.txt", "decode.hlo.txt", "insert.hlo.txt"]:
        text = (out / name).read_text()
        assert text.startswith("HloModule"), name
        assert "ENTRY" in text, name


def test_golden_matches_reload(built):
    out, manifest = built
    golden = json.loads((out / "golden.json").read_text())
    assert len(golden["generated"]) == golden["steps"]
    # Rebuild params from weights.bin and re-run greedy generation — must
    # reproduce the golden transcript (proves weights.bin is faithful).
    cfg = M.presets()["micro"]
    raw = (out / "weights.bin").read_bytes()
    floats = np.frombuffer(raw, dtype="<f4")
    params = {}
    for p in manifest["params"]:
        n = p["elems"]
        params[p["name"]] = np.asarray(floats[p["offset"]:p["offset"] + n]).reshape(p["shape"])
    import jax.numpy as jnp

    params = {k: jnp.asarray(v) for k, v in params.items()}
    toks = np.zeros((1, cfg.max_prompt), np.int32)
    toks[0, : golden["prompt_len"]] = golden["prompt"]
    gen = M.greedy_generate(
        cfg, params, jnp.asarray(toks), jnp.asarray([golden["prompt_len"]], jnp.int32),
        golden["steps"],
    )
    assert np.asarray(gen)[0].tolist() == golden["generated"]


def test_weights_little_endian_f32(built):
    out, manifest = built
    raw = (out / "weights.bin").read_bytes()
    # First tensor is the embedding; spot-check one value against struct.
    first = struct.unpack("<f", raw[:4])[0]
    floats = np.frombuffer(raw[:4], dtype="<f4")
    assert first == floats[0]
    assert manifest["params"][0]["name"] == "embed"
