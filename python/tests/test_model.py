"""L2 correctness: model shapes, KV-cache semantics, decode/prefill agreement."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

CFG = M.presets()["micro"]


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(0))


def _prompt(rng, b, lens):
    toks = np.zeros((b, CFG.max_prompt), np.int32)
    for i, l in enumerate(lens):
        toks[i, :l] = rng.integers(1, CFG.vocab, size=(l,))
    return jnp.asarray(toks), jnp.asarray(lens, jnp.int32)


def test_param_count_matches_manifest(params):
    total = sum(int(np.asarray(v).size) for v in params.values())
    assert total == M.param_count(CFG)


def test_prefill_shapes(params):
    rng = np.random.default_rng(0)
    toks, lens = _prompt(rng, 2, [5, 17])
    logits, k, v = M.prefill(CFG, params, toks, lens)
    assert logits.shape == (2, CFG.vocab)
    assert k.shape == (CFG.n_layers, 2, CFG.n_heads, CFG.max_seq, CFG.head_dim)
    assert v.shape == k.shape


def test_prefill_cache_zero_beyond_len(params):
    rng = np.random.default_rng(1)
    toks, lens = _prompt(rng, 2, [5, 17])
    _, k, v = M.prefill(CFG, params, toks, lens)
    assert np.all(np.asarray(k)[:, 0, :, 5:, :] == 0.0)
    assert np.all(np.asarray(v)[:, 1, :, 17:, :] == 0.0)
    assert not np.all(np.asarray(k)[:, 0, :, :5, :] == 0.0)


def test_prefill_logits_independent_of_padding(params):
    """Same prompt with different pad content must give identical logits."""
    rng = np.random.default_rng(2)
    toks, lens = _prompt(rng, 1, [9])
    logits1, _, _ = M.prefill(CFG, params, toks, lens)
    toks2 = np.asarray(toks).copy()
    toks2[0, 9:] = 7  # garbage in the pad region
    logits2, _, _ = M.prefill(CFG, params, jnp.asarray(toks2), lens)
    np.testing.assert_allclose(np.asarray(logits1), np.asarray(logits2), atol=1e-5)


def test_decode_step_extends_cache(params):
    rng = np.random.default_rng(3)
    b = CFG.decode_slots
    toks = jnp.asarray(rng.integers(1, CFG.vocab, size=(b,)), jnp.int32)
    k, v = M.empty_cache(CFG, b)
    lens = jnp.asarray([3] + [0] * (b - 1), jnp.int32)
    # Slot 0 alive with 3 tokens of (zero) history; others dead.
    logits, k2, v2 = M.decode_step(CFG, params, k, v, lens, toks)
    assert logits.shape == (b, CFG.vocab)
    # Slot 0 position 3 written:
    assert not np.all(np.asarray(k2)[:, 0, :, 3, :] == 0.0)
    # Dead slot caches untouched (still zero):
    assert np.all(np.asarray(k2)[:, 1:, :, :, :] == 0.0)


def test_decode_agrees_with_prefill(params):
    """Teacher-forcing the prompt through decode_step must reproduce the
    prefill last-token logits (the autoregressive consistency invariant)."""
    rng = np.random.default_rng(4)
    l = 6
    toks, lens = _prompt(rng, 1, [l])
    logits_pf, _, _ = M.prefill(CFG, params, toks, lens)

    b = CFG.decode_slots
    k, v = M.empty_cache(CFG, b)
    cur_lens = jnp.zeros((b,), jnp.int32)
    seq = np.asarray(toks)[0, :l]
    logits = None
    for i, t in enumerate(seq):
        step_toks = jnp.zeros((b,), jnp.int32).at[0].set(int(t))
        step_lens = cur_lens.at[0].set(i)
        # lens=0 means dead; first token of a live sequence needs lens>0
        # convention: we mark slot 0 alive by passing i (position), but
        # position 0 with lens 0 would read as dead — so the decode path
        # is only used from position >= 1; position 0 is exercised via a
        # 1-token prefill.
        if i == 0:
            one = jnp.asarray([[int(t)] + [0] * (CFG.max_prompt - 1)], jnp.int32)
            lg, k1, v1 = M.prefill(CFG, params, one, jnp.asarray([1], jnp.int32))
            k = M.insert_slot(CFG, k, v, k1, v1, jnp.int32(0))[0]
            v = M.insert_slot(CFG, k, v, k1, v1, jnp.int32(0))[1]
            logits = lg
            continue
        lg, k, v = M.decode_step(CFG, params, k, v, step_lens, step_toks)
        logits = lg[0:1]
    np.testing.assert_allclose(
        np.asarray(logits_pf), np.asarray(logits), atol=1e-3, rtol=1e-3
    )


def test_insert_slot_places_cache(params):
    rng = np.random.default_rng(5)
    toks, lens = _prompt(rng, 1, [4])
    _, k1, v1 = M.prefill(CFG, params, toks, lens)
    kb, vb = M.empty_cache(CFG, CFG.decode_slots)
    k2, v2 = M.insert_slot(CFG, kb, vb, k1, v1, jnp.int32(2))
    np.testing.assert_allclose(
        np.asarray(k2)[:, 2], np.asarray(k1)[:, 0], atol=0
    )
    assert np.all(np.asarray(k2)[:, 0] == 0.0)


def test_greedy_generate_deterministic(params):
    rng = np.random.default_rng(6)
    toks, lens = _prompt(rng, 1, [8])
    g1 = M.greedy_generate(CFG, params, toks, lens, 5)
    g2 = M.greedy_generate(CFG, params, toks, lens, 5)
    assert np.array_equal(np.asarray(g1), np.asarray(g2))
    assert g1.shape == (1, 5)
    assert np.all(np.asarray(g1) >= 0) and np.all(np.asarray(g1) < CFG.vocab)


# ---------------------------------------------------------------------------
# Packed-state wrappers (what the AOT artifacts actually lower)
# ---------------------------------------------------------------------------


def test_pack_unpack_roundtrip(params):
    rng = np.random.default_rng(11)
    b = CFG.decode_slots
    k, v = M.empty_cache(CFG, b)
    k = k + 1.5
    v = v - 0.5
    logits = jnp.asarray(rng.standard_normal((b, CFG.vocab)), jnp.float32)
    state = M.pack_state(CFG, k, v, logits)
    assert state.shape == (M.state_elems(CFG, b),)
    k2, v2, l2 = M.unpack_state(CFG, state, b)
    np.testing.assert_array_equal(np.asarray(k), np.asarray(k2))
    np.testing.assert_array_equal(np.asarray(v), np.asarray(v2))
    np.testing.assert_array_equal(np.asarray(logits), np.asarray(l2))


def test_packed_prefill_matches_unpacked(params):
    rng = np.random.default_rng(12)
    toks, lens = _prompt(rng, 1, [7])
    logits, k, v = M.prefill(CFG, params, toks, lens)
    state = M.prefill_packed(CFG, params, toks, lens)
    k2, v2, l2 = M.unpack_state(CFG, state, 1)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(l2), atol=1e-6)
    np.testing.assert_allclose(np.asarray(k), np.asarray(k2), atol=1e-6)


def test_packed_decode_matches_unpacked(params):
    rng = np.random.default_rng(13)
    b = CFG.decode_slots
    toks, plens = _prompt(rng, 1, [5])
    state1 = M.prefill_packed(CFG, params, toks, plens)
    kb, vb = M.empty_cache(CFG, b)
    lb = jnp.zeros((b, CFG.vocab), jnp.float32)
    state_b = M.pack_state(CFG, kb, vb, lb)
    state_b = M.insert_packed(CFG, state_b, state1, jnp.int32(0))
    lens = jnp.zeros((b,), jnp.int32).at[0].set(5)
    step_toks = jnp.zeros((b,), jnp.int32).at[0].set(42)
    out_state = M.decode_packed(CFG, params, state_b, lens, step_toks)
    k2, v2, l2 = M.unpack_state(CFG, out_state, b)
    # Reference: unpacked path.
    kb2, vb2, _ = M.unpack_state(CFG, state_b, b)
    ref_logits, ref_k, ref_v = M.decode_step(CFG, params, kb2, vb2, lens, step_toks)
    np.testing.assert_allclose(np.asarray(l2), np.asarray(ref_logits), atol=1e-6)
    np.testing.assert_allclose(np.asarray(k2), np.asarray(ref_k), atol=1e-6)


def test_read_logits_slices_correctly(params):
    rng = np.random.default_rng(14)
    toks, lens = _prompt(rng, 1, [6])
    state = M.prefill_packed(CFG, params, toks, lens)
    l = M.read_logits(CFG, state, 1)
    ref, _, _ = M.prefill(CFG, params, toks, lens)
    np.testing.assert_allclose(np.asarray(l), np.asarray(ref), atol=1e-6)


def test_ref_attention_impl_close_to_pallas(params):
    """The --attention ref artifacts must stay numerically pinned to the
    pallas path (the §Perf optimization's correctness condition)."""
    rng = np.random.default_rng(15)
    toks, lens = _prompt(rng, 1, [9])
    logits_pallas, _, _ = M.prefill(CFG, params, toks, lens)
    old = M.ATTENTION_IMPL
    try:
        M.ATTENTION_IMPL = "ref"
        logits_ref, _, _ = M.prefill(CFG, params, toks, lens)
    finally:
        M.ATTENTION_IMPL = old
    np.testing.assert_allclose(
        np.asarray(logits_pallas), np.asarray(logits_ref), atol=2e-4, rtol=2e-4
    )
