"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes and dtypes; fixed regression cases pin the edge
conditions (empty sequences, single token, full cache, tile boundaries).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention as A
from compile.kernels import ref as R

jax.config.update("jax_platform_name", "cpu")


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else dict(
        atol=2e-5, rtol=2e-5
    )


def _rand(rng, shape, dtype):
    return jnp.asarray(rng.standard_normal(shape), jnp.float32).astype(dtype)


# ---------------------------------------------------------------------------
# Decode kernel
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 5),
    h=st.integers(1, 4),
    t=st.integers(1, 300),
    d=st.sampled_from([16, 32, 64, 128]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    data=st.data(),
)
def test_decode_matches_ref_hypothesis(b, h, t, d, dtype, data):
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    q = _rand(rng, (b, h, d), dtype)
    k = _rand(rng, (b, h, t, d), dtype)
    v = _rand(rng, (b, h, t, d), dtype)
    lens = jnp.asarray(rng.integers(0, t + 1, size=(b,)), jnp.int32)
    out = A.decode_attention(q, k, v, lens)
    ref = R.ref_decode_attention(q, k, v, lens)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **_tol(dtype)
    )


@pytest.mark.parametrize("lens", [[0], [1], [128], [129], [200]])
def test_decode_edge_lengths(lens):
    rng = np.random.default_rng(1)
    t = 200
    q = _rand(rng, (1, 2, 32), jnp.float32)
    k = _rand(rng, (1, 2, t, 32), jnp.float32)
    v = _rand(rng, (1, 2, t, 32), jnp.float32)
    l = jnp.asarray(lens, jnp.int32)
    out = A.decode_attention(q, k, v, l)
    ref = R.ref_decode_attention(q, k, v, l)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_decode_zero_len_returns_zeros():
    rng = np.random.default_rng(2)
    q = _rand(rng, (2, 2, 16), jnp.float32)
    k = _rand(rng, (2, 2, 64, 16), jnp.float32)
    v = _rand(rng, (2, 2, 64, 16), jnp.float32)
    out = A.decode_attention(q, k, v, jnp.asarray([0, 5], jnp.int32))
    assert np.all(np.asarray(out)[0] == 0.0)
    assert not np.all(np.asarray(out)[1] == 0.0)


def test_decode_ignores_cache_beyond_len():
    """Garbage beyond lens must not affect the output."""
    rng = np.random.default_rng(3)
    q = _rand(rng, (1, 1, 16), jnp.float32)
    k = _rand(rng, (1, 1, 64, 16), jnp.float32)
    v = _rand(rng, (1, 1, 64, 16), jnp.float32)
    lens = jnp.asarray([10], jnp.int32)
    out1 = A.decode_attention(q, k, v, lens)
    k2 = k.at[:, :, 10:, :].set(1e6)
    v2 = v.at[:, :, 10:, :].set(-1e6)
    out2 = A.decode_attention(q, k2, v2, lens)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-6)


# ---------------------------------------------------------------------------
# Prefill kernel
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 3),
    h=st.integers(1, 3),
    p=st.integers(1, 200),
    d=st.sampled_from([16, 32, 64]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    data=st.data(),
)
def test_prefill_matches_ref_hypothesis(b, h, p, d, dtype, data):
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    q = _rand(rng, (b, h, p, d), dtype)
    k = _rand(rng, (b, h, p, d), dtype)
    v = _rand(rng, (b, h, p, d), dtype)
    lens = jnp.asarray(rng.integers(0, p + 1, size=(b,)), jnp.int32)
    out = A.prefill_attention(q, k, v, lens)
    ref = R.ref_prefill_attention(q, k, v, lens)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **_tol(dtype)
    )


@pytest.mark.parametrize("p,lens", [(64, 64), (64, 1), (65, 65), (128, 100), (130, 130)])
def test_prefill_tile_boundaries(p, lens):
    rng = np.random.default_rng(4)
    q = _rand(rng, (1, 2, p, 32), jnp.float32)
    k = _rand(rng, (1, 2, p, 32), jnp.float32)
    v = _rand(rng, (1, 2, p, 32), jnp.float32)
    l = jnp.asarray([lens], jnp.int32)
    out = A.prefill_attention(q, k, v, l)
    ref = R.ref_prefill_attention(q, k, v, l)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5, rtol=3e-5)


def test_prefill_causality():
    """Changing future tokens must not change past rows."""
    rng = np.random.default_rng(5)
    p = 32
    q = _rand(rng, (1, 1, p, 16), jnp.float32)
    k = _rand(rng, (1, 1, p, 16), jnp.float32)
    v = _rand(rng, (1, 1, p, 16), jnp.float32)
    lens = jnp.asarray([p], jnp.int32)
    out1 = A.prefill_attention(q, k, v, lens)
    k2 = k.at[:, :, 20:, :].add(3.0)
    v2 = v.at[:, :, 20:, :].add(-2.0)
    out2 = A.prefill_attention(q, k2, v2, lens)
    np.testing.assert_allclose(
        np.asarray(out1)[:, :, :20], np.asarray(out2)[:, :, :20], atol=1e-6
    )
    assert not np.allclose(np.asarray(out1)[:, :, 20:], np.asarray(out2)[:, :, 20:])


def test_prefill_first_row_attends_self_only():
    rng = np.random.default_rng(6)
    p = 8
    q = _rand(rng, (1, 1, p, 16), jnp.float32)
    k = _rand(rng, (1, 1, p, 16), jnp.float32)
    v = _rand(rng, (1, 1, p, 16), jnp.float32)
    out = A.prefill_attention(q, k, v, jnp.asarray([p], jnp.int32))
    np.testing.assert_allclose(
        np.asarray(out)[0, 0, 0], np.asarray(v)[0, 0, 0], atol=1e-5
    )


def test_vmem_report_within_budget():
    rep = A.vmem_report(b=8, h=32, p=2048, d=128, t=4096)
    assert rep["decode_bytes_per_program"] < rep["vmem_budget_bytes"]
    assert rep["prefill_bytes_per_program"] < rep["vmem_budget_bytes"]
