//! Chaos drill: a correlated zone outage lands mid-diurnal-peak. How
//! much SLO attainment does the fleet keep — with a health-aware
//! control plane (routers skip dead replicas, in-flight work is
//! re-routed, the autoscaler re-provisions the lost capacity) versus a
//! health-blind one (corpses stay in the routing table looking idle,
//! and nothing replaces them)?
//!
//!     cargo run --release --example chaos_drill

use econoserve::figures::common;
use econoserve::fleet::{self, ChaosOutcome, FleetConfig};
use econoserve::trace::{ArrivalProcess, TraceGen, TraceSpec};

fn main() {
    let trace = "sharegpt";
    let mut cfg = common::cfg("opt-13b", trace);
    // Bit-reproducible drill: never charge measured scheduler wall-clock
    // into the simulated clock.
    cfg.sched_time_scale = 0.0;
    cfg.seed = 42;

    // A day-curve sized so the peak needs most of the fleet — the zone
    // outage ("zone-outage": half the replicas per hit, every ~300 s of
    // a 600 s run) lands while the fleet is busy, not idle.
    let period = 300.0;
    let mean_rate = 0.35 * common::capacity_estimate(&cfg, trace) * 3.0;
    let process = ArrivalProcess::Diurnal { mean_rate, amplitude: 0.6, period };
    let gen = TraceGen::new(TraceSpec::by_name(trace).unwrap());
    let items = gen.generate_arrivals(process, 2.0 * period, cfg.profile.max_total_len, cfg.seed);

    let mut fc = FleetConfig::new(cfg, "econoserve", trace);
    fc.oracle = true;
    fc.router = "least-kvc".to_string();
    fc.autoscaler = "reactive".to_string();
    fc.init_replicas = 2;
    fc.min_replicas = 2;
    fc.max_replicas = 4;
    fc.boot_latency = 8.0;
    fc.max_sim_time = 4.0 * period;
    fc.faults = "zone-outage".to_string();

    println!(
        "chaos drill: zone outage under a diurnal peak (mean {mean_rate:.2} req/s, \
         n={}, fleet {}..{}, router {}, autoscaler {})\n",
        items.len(),
        fc.min_replicas,
        fc.max_replicas,
        fc.router,
        fc.autoscaler,
    );

    let aware = fleet::chaos_run(&fc, &items);
    let mut blind_fc = fc.clone();
    blind_fc.health_aware = false;
    let blind = fleet::chaos_run(&blind_fc, &items);

    report("health-aware", &aware);
    report("health-blind", &blind);
    println!(
        "verdict: health-aware routing + reactive re-provisioning keeps {:.1}% of \
         fault-free SSR; routing into corpses keeps {:.1}%",
        aware.ssr_retention() * 100.0,
        blind.ssr_retention() * 100.0,
    );
}

fn report(label: &str, out: &ChaosOutcome) {
    let c = &out.chaos;
    let f = &c.faults;
    println!(
        "[{label}]\n  fault-free baseline: SSR {:.1}%  goodput {:.2} req/s\n  \
         under zone outages:  SSR {:.1}%  goodput {:.2} req/s  \
         (retention: SSR {:.1}%, goodput {:.1}%)\n  \
         faults: {} replicas crashed across {} outage(s), {} requests re-routed, \
         {} lost, {} boots\n",
        out.baseline.ssr * 100.0,
        out.baseline.goodput_rps,
        c.ssr * 100.0,
        c.goodput_rps,
        out.ssr_retention() * 100.0,
        out.goodput_retention() * 100.0,
        f.crashes,
        f.zone_outages,
        f.rerouted,
        f.lost,
        c.boots,
    );
}
