//! Trace explorer: generate the synthetic Alpaca / ShareGPT / BookCorpus
//! traces and verify their statistics against the paper's Table 2.
//!
//!     cargo run --release --example trace_explorer

use econoserve::trace::{self, TraceGen, TraceSpec};

fn main() {
    println!("{:<12} {:>9} {:>9} {:>9} | {:>9} {:>9} {:>9} | {:>8}", "trace", "in_avg", "in_min", "in_max", "out_avg", "out_min", "out_max", "rate");
    for spec in TraceSpec::all() {
        let gen = TraceGen::new(spec);
        let items = gen.generate(20_000, spec.default_rate, 4096, 42);
        let s = trace::stats(&items);
        println!(
            "{:<12} {:>9.1} {:>9} {:>9} | {:>9.1} {:>9} {:>9} | {:>8.2}",
            spec.name, s.in_avg, s.in_min, s.in_max, s.out_avg, s.out_min, s.out_max, s.rate
        );
        println!(
            "{:<12} {:>9.1} {:>9} {:>9} | {:>9.1} {:>9} {:>9} | {:>8.2}  (paper)",
            "", spec.input.avg, spec.input.min, spec.input.max, spec.output.avg, spec.output.min, spec.output.max, spec.default_rate
        );
    }
    // Show a CDF of same-RL prediction groups (precondition of Fig 2).
    println!("\nCSV export: target/alpaca.csv");
    let gen = TraceGen::new(TraceSpec::alpaca());
    let items = gen.generate(1000, 36.0, 4096, 1);
    let _ = trace::save_csv(&items, "target/alpaca.csv");
}
