//! Quickstart: simulate EconoServe on a ShareGPT-like workload and print
//! the summary — the 60-second tour of the public API.
//!
//!     cargo run --release --example quickstart

use econoserve::config::{ModelProfile, SystemConfig};
use econoserve::coordinator::{harness, RunLimits};
use econoserve::trace::{TraceGen, TraceSpec};

fn main() {
    // 1. Pick a hardware/model profile and tune the paper's knobs.
    let mut cfg = SystemConfig::new(ModelProfile::opt_13b());
    cfg.padding_ratio = 0.15; // ShareGPT sweet spot (§2.3)
    cfg.reserve_frac = 0.03;
    cfg.t_p = 0.05; // SLO constants (see figures::common::cfg for the
    cfg.t_g = 0.022; // calibrated derivation)

    // 2. Generate a workload calibrated to the paper's Table 2 stats.
    let spec = TraceSpec::sharegpt();
    let gen = TraceGen::new(spec);
    let items = gen.generate_for(60.0, 2.0, cfg.profile.max_total_len, 42);
    println!("workload: {} requests over 60s @ 2 req/s", items.len());

    // 3. Run the EconoServe scheduler on the calibrated engine.
    let res = harness::simulate(&cfg, "econoserve", "sharegpt", &items, false, RunLimits::for_time(600.0));
    let s = &res.summary;
    println!(
        "done {}/{} | throughput {:.2} req/s | mean JCT {:.2}s | SSR {:.0}% | \
         GPU {:.0}% KVC {:.0}%",
        s.n_done,
        s.n_total,
        s.throughput_rps,
        s.mean_jct,
        s.ssr * 100.0,
        s.gpu_util * 100.0,
        s.kvc_util * 100.0
    );

    // 4. Compare against vLLM in one line.
    let v = harness::simulate(&cfg, "vllm", "sharegpt", &items, false, RunLimits::for_time(600.0));
    println!("vLLM baseline: JCT {:.2}s, SSR {:.0}%", v.summary.mean_jct, v.summary.ssr * 100.0);
}
