//! Telemetry tour: the unified metric registry end to end, std-only.
//!
//! 1. Registry basics — counters, gauges, histograms, and the canonical
//!    Prometheus text exposition (strict enough to round-trip through
//!    its own parser: `econoserve promlint` is this check as a CLI).
//! 2. The instrumented simulator — a fleet run carries one registry per
//!    replica; the result merges them (plus fault-layer counters) into a
//!    single snapshot that is a pure function of (config, seed), so it
//!    is bit-identical at any worker-thread count and reconciles exactly
//!    with the summary statistics.
//! 3. The structured request log — the bounded ring every serving-path
//!    lifecycle event lands in (`submit`, `first_token`, `finish`, ...).
//!
//!     cargo run --release --example telemetry_tour

use econoserve::figures::common;
use econoserve::fleet::{self, FleetConfig};
use econoserve::telemetry::{Buckets, Registry, RequestLog, Snapshot};
use econoserve::trace::{TraceGen, TraceSpec};

fn main() {
    // -----------------------------------------------------------------
    // 1. Registry basics
    // -----------------------------------------------------------------
    println!("== 1. registry basics ==\n");
    let registry = Registry::new();
    let served = registry.counter("tour_requests_total", "Requests served", &[("zone", "a")]);
    let depth = registry.gauge("tour_queue_depth", "Waiting requests", &[]);
    let latency = registry.histogram(
        "tour_latency_seconds",
        "Request latency",
        Buckets::exponential(0.01, 10.0, 3),
        &[],
    );
    served.add(3);
    depth.set(2.0);
    latency.observe(0.05);
    latency.observe(0.7);
    let text = registry.render();
    println!("{text}");
    // The exposition format is strict: parse -> render is the identity
    // on canonical text (what `econoserve promlint <file>` asserts).
    let reparsed = Snapshot::parse(&text).expect("own render must parse");
    assert_eq!(reparsed.render(), text, "canonical text round-trips");
    println!("(round-trips through Snapshot::parse — promlint-clean)\n");

    // -----------------------------------------------------------------
    // 2. The instrumented simulator
    // -----------------------------------------------------------------
    println!("== 2. fleet run -> merged snapshot ==\n");
    let trace = "sharegpt";
    let mut cfg = common::cfg("opt-13b", trace);
    cfg.sched_time_scale = 0.0; // bit-reproducible
    cfg.seed = 7;
    let gen = TraceGen::new(TraceSpec::by_name(trace).unwrap());
    let items = gen.generate(200, 6.0, cfg.profile.max_total_len, cfg.seed);

    let mut fc = FleetConfig::new(cfg, "econoserve", trace);
    fc.oracle = true;
    fc.router = "least-kvc".to_string();
    fc.init_replicas = 2;
    fc.max_replicas = 2;
    fc.max_sim_time = 600.0;
    let res = fleet::run(&fc, &items);

    let snap = Snapshot::parse(&res.metrics).expect("fleet metrics parse");
    println!(
        "{} families, {} samples from {} replicas",
        snap.family_names().len(),
        snap.sample_count(),
        res.replicas.len()
    );
    for (label, name, labels) in [
        ("done", "econoserve_requests_total", &[("outcome", "done")][..]),
        ("rejected", "econoserve_requests_total", &[("outcome", "rejected")][..]),
        ("slo hits", "econoserve_slo_total", &[("outcome", "hit")][..]),
        ("iterations", "econoserve_iterations_total", &[][..]),
        ("decode tokens", "econoserve_tokens_total", &[("phase", "decode")][..]),
        ("preemptions", "econoserve_preemptions_total", &[][..]),
    ] {
        println!("  {label:>14}: {}", snap.value(name, labels).unwrap_or(0.0));
    }
    // The registry is not parallel bookkeeping: it reconciles exactly
    // with the independently computed summary.
    assert_eq!(
        snap.value("econoserve_requests_total", &[("outcome", "done")]),
        Some(res.summary.n_done as f64),
        "counter must agree with the summary"
    );
    println!(
        "  reconciles with summary.n_done = {} (same events, counted once)\n",
        res.summary.n_done
    );

    // -----------------------------------------------------------------
    // 3. The structured request log
    // -----------------------------------------------------------------
    println!("== 3. structured request log ==\n");
    let log = RequestLog::with_capacity(4);
    log.log(1, 0.00, "submit", "prompt_len=12 max_new=32");
    log.log(1, 0.05, "first_token", "");
    log.log(2, 0.06, "reject", "queue_full");
    log.log(1, 0.90, "finish", "complete");
    print!("{}", log.render_jsonl());
    println!(
        "\nbounded ring: capacity 4, {} held, {} dropped so far",
        log.len(),
        log.dropped()
    );
    println!("per-request view of id=1: {} events", log.for_request(1).len());
}
