//! END-TO-END driver: serve a batched Poisson workload on the REAL model
//! through the PJRT runtime — proving L1 (Pallas kernels) + L2 (JAX
//! model) + L3 (rust coordinator) compose with Python off the request
//! path. Requires `make artifacts`.
//!
//!     cargo run --release --example serve_real_model
//!
//! Demonstrates the unified request-lifecycle API: admission-controlled
//! `submit(SubmitOptions) -> RequestHandle`, per-token streaming events,
//! and structured `FinishReason` terminals. Reports per-request latency,
//! TTFT, TBT and throughput; recorded in EXPERIMENTS.md §End-to-end.

use econoserve::api::{FinishReason, StreamEvent, SubmitOptions};
use econoserve::runtime::PjrtModel;
use econoserve::server::RealServer;
use econoserve::trace::{TraceGen, TraceSpec};
use econoserve::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let n: usize = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(24);

    let model = PjrtModel::load(&dir)?;
    println!(
        "model: {} params, {} layers, vocab {}, {} decode slots, max_seq {}",
        model.dims.param_count,
        model.dims.n_layers,
        model.dims.vocab,
        model.dims.decode_slots,
        model.dims.max_seq
    );
    let dims = model.dims.clone();
    let mut server = RealServer::new(model);

    // ShareGPT-shaped lengths scaled into the demo model's context.
    let gen = TraceGen::new(TraceSpec::sharegpt());
    let items = gen.generate(n, 4.0, (dims.max_seq - 8) as u32, 7);
    let mut rng = Rng::new(11);
    let scale = |len: u32, cap: usize| -> usize { ((len as usize).min(cap)).max(2) };
    let mut handles = Vec::new();
    for it in items.iter() {
        let plen = scale(it.prompt_len, dims.max_prompt);
        let rl = scale(it.true_rl, dims.max_seq - plen - 2);
        let prompt: Vec<i32> =
            (0..plen).map(|_| rng.range_u64(1, dims.vocab as u64 - 1) as i32).collect();
        let opts = SubmitOptions::new(prompt, rl).with_predicted_rl(rl as u32).with_slo(60.0);
        match server.submit(opts) {
            Ok(h) => handles.push(h),
            Err(e) => eprintln!("rejected at admission: {e}"),
        }
    }

    server.run_to_completion()?;
    let st = server.stats();
    println!(
        "\nserved {} requests end-to-end on the PJRT CPU backend:\n\
         throughput  {:.2} req/s | {:.1} tok/s\n\
         latency     mean {:.3}s  p95 {:.3}s\n\
         TTFT        mean {:.3}s\n\
         TBT         mean {:.1}ms\n\
         decode iterations {} | mean batch occupancy {:.2}/{}",
        st.completed,
        st.throughput_rps,
        st.throughput_tps,
        st.mean_latency,
        st.p95_latency,
        st.mean_ttft,
        st.mean_tbt * 1e3,
        st.decode_iterations,
        st.mean_batch_occupancy,
        dims.decode_slots
    );

    // Consume one handle's event stream to show per-token streaming: the
    // events were pushed as each decode iteration produced its token.
    if let Some(h) = handles.into_iter().next() {
        let id = h.id();
        let mut tokens = 0usize;
        let mut finish = FinishReason::Error;
        for ev in h {
            match ev {
                StreamEvent::Token(_) => tokens += 1,
                StreamEvent::Finished(c) => finish = c.finish,
            }
        }
        println!("  req {id}: {tokens} streamed token events, finish={finish}");
    }
    // A few sample generations to show real tokens flow end to end.
    for c in server.finished().iter().take(3) {
        println!(
            "  req {} -> {} tokens ({}), first 8: {:?}",
            c.id,
            c.tokens.len(),
            c.finish,
            &c.tokens[..c.tokens.len().min(8)]
        );
    }
    Ok(())
}
