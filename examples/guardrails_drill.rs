//! Guardrails drill: lone replica crashes land under a diurnal peak
//! while reboots are slow. How much goodput and SLO attainment do the
//! reliability guardrails buy, layer by layer — no guardrails (legacy
//! immediate re-route), budgeted retries with backoff, and retries plus
//! request hedging — all with deadline-aware aborts culling provably
//! hopeless work in the two guarded modes?
//!
//!     cargo run --release --example guardrails_drill

use econoserve::figures::common;
use econoserve::fleet::{self, FleetConfig, FleetSummary};
use econoserve::trace::{ArrivalProcess, TraceGen, TraceSpec};

fn main() {
    let trace = "sharegpt";
    let mut cfg = common::cfg("opt-13b", trace);
    // Bit-reproducible drill: never charge measured scheduler wall-clock
    // into the simulated clock.
    cfg.sched_time_scale = 0.0;
    cfg.seed = 47;

    // A day-curve whose peak pinches a 2-replica fleet, with reboots
    // slow enough (25 s) that every crash leaves a real capacity hole —
    // the regime where retries, hedges and aborts earn their keep.
    let period = 240.0;
    let mean_rate = 0.65 * common::capacity_estimate(&cfg, trace) * 2.0;
    let process = ArrivalProcess::Diurnal { mean_rate, amplitude: 0.6, period };
    let gen = TraceGen::new(TraceSpec::by_name(trace).unwrap());
    let items = gen.generate_arrivals(process, 2.0 * period, cfg.profile.max_total_len, cfg.seed);

    let mut fc = FleetConfig::new(cfg, "econoserve", trace);
    fc.oracle = true;
    fc.router = "least-kvc".to_string();
    fc.autoscaler = "reactive".to_string();
    fc.init_replicas = 2;
    fc.min_replicas = 2;
    fc.max_replicas = 2;
    fc.boot_latency = 25.0;
    fc.control_interval = 5.0;
    fc.max_sim_time = 6.0 * period;
    fc.faults = "crashes".to_string();

    println!(
        "guardrails drill: crashes under a diurnal peak (mean {mean_rate:.2} req/s, \
         n={}, fleet of {}, boot latency {} s, router {})\n",
        items.len(),
        fc.max_replicas,
        fc.boot_latency,
        fc.router,
    );

    let modes = ["off", "retry+abort", "retry+hedge+abort"];
    let mut results: Vec<(&str, FleetSummary)> = Vec::new();
    for mode in modes {
        let mut mfc = fc.clone();
        mfc.guardrails = mode.to_string();
        results.push((mode, fleet::run(&mfc, &items).summary));
    }

    println!(
        "{:<18} {:>10} {:>7} {:>8} {:>8} {:>7} {:>9} {:>8}",
        "guardrails", "goodput", "ssr%", "retried", "recov", "lost", "hedgewon", "aborted"
    );
    for (mode, s) in &results {
        println!(
            "{:<18} {:>10.3} {:>7.1} {:>8} {:>8} {:>7} {:>9} {:>8}",
            mode,
            s.goodput_rps,
            s.ssr * 100.0,
            s.faults.retried,
            s.faults.recovered,
            s.faults.lost,
            s.faults.hedges_won,
            s.faults.aborted,
        );
        // The generalized conservation identity holds in every mode.
        assert_eq!(s.n_total, s.n_done + s.faults.lost + s.faults.aborted);
    }

    let off = &results[0].1;
    let full = &results[2].1;
    println!(
        "\nverdict: retry+hedge+abort recovers {} displaced request(s) and shifts \
         goodput {:+.3} req/s / SSR {:+.1} pp against bare re-routing.",
        full.faults.recovered,
        full.goodput_rps - off.goodput_rps,
        (full.ssr - off.ssr) * 100.0,
    );
}
