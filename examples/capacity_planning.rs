//! Capacity planning (Fig 12 style): how many GPUs does EconoServe need
//! to match a DistServe deployment's goodput?
//!
//!     cargo run --release --example capacity_planning

use econoserve::cluster::{DistServeConfig, DistServeSim};
use econoserve::fleet::min_replicas_for_goodput;
use econoserve::figures::common;

fn main() {
    let trace = "sharegpt";
    for model in ["opt-13b", "llama-33b"] {
        let cfg = common::cfg(model, trace);
        let rate = common::capacity_estimate(&cfg, trace) * 0.8;
        let items = common::workload(&cfg, trace, rate, 45.0, 42);

        let dcfg = DistServeConfig::homogeneous(cfg.profile.clone(), &cfg);
        let dist = DistServeSim::new(dcfg).run(&items, 600.0);
        let dist_gpus = 2 * cfg.profile.gpus_per_replica;
        println!(
            "{model}: DistServe goodput {:.2} req/s on {} GPUs (transfer {:.1}% of JCT)",
            dist.goodput,
            dist_gpus,
            dist.transfer_share * 100.0
        );
        match min_replicas_for_goodput(&cfg, "econoserve", trace, &items, false, dist.goodput, 4, 600.0)
        {
            Some(k) => {
                let gpus = k as u32 * cfg.profile.gpus_per_replica;
                println!(
                    "  EconoServe matches it with {gpus} GPU(s): {:.0}% fewer\n",
                    (1.0 - gpus as f64 / dist_gpus as f64) * 100.0
                );
            }
            None => println!("  EconoServe cannot match within 4 replicas\n"),
        }
    }
}
